"""Batched config-axis replay: bit-identity vs the per-policy reference.

The load-bearing guarantee of the batched sweep path (ISSUE 3): for ANY
policy grid, ANY chunking, and any process-pool width, every
:class:`PolicyOutcome` field — energies, penalties, event counts, per-job
CDFs — equals the scalar per-policy reference path's value *exactly*.
"""
import tempfile

import numpy as np
from _hyp import given, settings, st

from repro.cluster import generate_cluster
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.energy import BatchedStreamingIntegrator, StreamingIntegrator
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.telemetry import TelemetryStore
from repro.whatif import (BatchedPolicyReplayer, DownscalePolicy, NoOpPolicy,
                          ParkingPolicy, PolicyReplayer, PowerCapPolicy,
                          default_policy_grid, frontier_to_dict, make_batches,
                          run_sweep, sweep_frame)

# --------------------------------------------------------------------------- #
# BatchedStreamingIntegrator == n_configs independent scalar integrators
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_batched_integrator_matches_independent_scalars(seed):
    rng = np.random.default_rng(seed % 100000)
    n, n_cfg = 2000, 5
    states = rng.choice([0, 1, 2], size=n, p=[0.2, 0.3, 0.5]).astype(np.int8)
    power = rng.normal(200, 40, (n_cfg, n))
    chunk = int(rng.integers(1, n + 1))
    batched = BatchedStreamingIntegrator(n_configs=n_cfg, min_duration_s=5.0)
    singles = [StreamingIntegrator(min_duration_s=5.0) for _ in range(n_cfg)]
    for s in range(0, n, chunk):
        batched.update(states[s:s + chunk], power[:, s:s + chunk])
        for c in range(n_cfg):
            singles[c].update(states[s:s + chunk], power[c, s:s + chunk])
    bds, intervals = batched.finalize_batch()
    for c in range(n_cfg):
        bd, ivs = singles[c].finalize()
        assert bd.energy_j == bds[c].energy_j
        assert bd.time_s == bds[c].time_s
        assert ivs == intervals


# --------------------------------------------------------------------------- #
# Random grids, random chunkings, workers in {1, 2}: sweep equality
# --------------------------------------------------------------------------- #
def random_grid(rng):
    """A small random policy grid mixing families, knobs AND low-activity
    thresholds (so family batches split and regroup)."""
    grid = [NoOpPolicy()]
    for _ in range(int(rng.integers(1, 4))):
        grid.append(DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)),
            cooldown_y_s=float(rng.uniform(1.0, 10.0)),
            interval_eps_s=float(rng.choice([0.5, 1.0, 2.0])),
            activity_threshold=float(rng.choice([0.05, 0.03])),
            mode=rng.choice([DownscaleMode.SM_ONLY, DownscaleMode.SM_AND_MEM]),
        )))
    for _ in range(int(rng.integers(1, 3))):
        n_dev = int(rng.choice([2, 4]))
        grid.append(ParkingPolicy(
            pool=PoolConfig(n_devices=n_dev, policy=PoolPolicy.CONSOLIDATED,
                            n_active=int(rng.integers(1, n_dev))),
            resume_latency_s=float(rng.uniform(2.0, 40.0))))
    for _ in range(int(rng.integers(1, 3))):
        grid.append(PowerCapPolicy(
            cap_fraction=float(rng.uniform(0.3, 0.9))))
    order = rng.permutation(len(grid))
    return [grid[i] for i in order]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_batched_sweep_matches_reference_any_grid_chunking_workers(seed):
    rng = np.random.default_rng(seed % 100000)
    grid = random_grid(rng)
    shard_s = int(rng.choice([300, 700, 1500]))
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=6, horizon_s=1500,
                         seed=int(rng.integers(0, 100)),
                         store=store, shard_s=shard_s)
        # >1 host label, so workers=2 really exercises the process pool
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        ref = run_sweep(store, grid, workers=1, min_job_duration_s=300,
                        batched=False)
        for workers in (1, 2):
            # compact=False: this test pins the row-batched engine to the
            # per-policy reference bit-for-bit; the run-IR fast path has its
            # own equivalence suite in tests/test_whatif_ir.py
            bat = run_sweep(store, grid, workers=workers,
                            min_job_duration_s=300, batched=True,
                            compact=False)
            assert frontier_to_dict(bat) == frontier_to_dict(ref)


def test_batched_replayer_chunking_bit_identical():
    cs = generate_cluster(n_devices=3, horizon_s=2700, seed=21)
    grid = [NoOpPolicy(), DownscalePolicy(),
            ParkingPolicy(pool=PoolConfig(n_devices=2,
                                          policy=PoolPolicy.CONSOLIDATED,
                                          n_active=1)),
            PowerCapPolicy(cap_fraction=0.5)]
    mono = BatchedPolicyReplayer(grid, min_job_duration_s=600)
    mono.update(cs.frame)
    a = mono.finalize()
    refs = []
    for pol in grid:
        r = PolicyReplayer(pol, min_job_duration_s=600)
        r.update(cs.frame)
        refs.append(r.finalize())
    for chunk_rows in (997, 1800):
        rep = BatchedPolicyReplayer(grid, min_job_duration_s=600)
        for chunk in cs.frame.iter_chunks(chunk_rows):
            rep.update(chunk)
        b = rep.finalize()
        for res_a, res_b, res_ref in zip(a, b, refs):
            for res in (res_b, res_ref):
                assert [j.job_id for j in res_a.jobs] == \
                    [j.job_id for j in res.jobs]
                for ja, jr in zip(res_a.jobs, res.jobs):
                    assert ja.baseline.energy_j == jr.baseline.energy_j
                    assert ja.counterfactual.energy_j == jr.counterfactual.energy_j
                    assert ja.counterfactual.time_s == jr.counterfactual.time_s
                    assert ja.penalty_s == jr.penalty_s
                    assert ja.wake_events == jr.wake_events
                    assert ja.throttled_time_s == jr.throttled_time_s
                assert res_a.counterfactual.energy_j == res.counterfactual.energy_j
                assert res_a.penalty_s == res.penalty_s


# --------------------------------------------------------------------------- #
# Fallback: unknown policy types replay through their scalar apply
# --------------------------------------------------------------------------- #
class _TrimPolicy:
    """A policy type the batcher has never heard of: shaves 10% board power
    off every resident sample (and alternates reporting residency to stress
    the fallback's row-structure stabilization)."""

    @property
    def name(self):
        return "trim"

    def describe(self):
        return {"policy": "trim"}

    def init_carry(self):
        return 0

    def apply(self, seg, plat, carry, dt_s=1.0):
        from repro.whatif import SegmentEffect
        power = np.asarray(seg["power"], dtype=np.float64)
        resident = seg["program_resident"].astype(bool)
        # report residency explicitly on every other segment only
        out_resident = resident if carry % 2 else None
        return SegmentEffect(
            power_w=np.where(resident, 0.9 * power, power),
            resident=out_resident,
            throttled=resident,
        ), carry + 1

    def event_penalty_s(self, plat):
        return 0.0


def test_fallback_batch_matches_scalar_replay():
    cs = generate_cluster(n_devices=3, horizon_s=2700, seed=9)
    grid = [NoOpPolicy(), _TrimPolicy(), DownscalePolicy()]
    batches = make_batches(grid)
    assert [type(b).__name__ for b, _ in batches] == \
        ["NoOpBatch", "FallbackBatch", "DownscaleBatch"]
    frontier = sweep_frame(cs.frame, grid, min_job_duration_s=300,
                           batched=True)
    ref = sweep_frame(cs.frame, grid, min_job_duration_s=300, batched=False)
    assert frontier_to_dict(frontier) == frontier_to_dict(ref)
    # chunked feeding exercises the alternating-residency carry
    rep = BatchedPolicyReplayer(grid, min_job_duration_s=300)
    for chunk in cs.frame.iter_chunks(500):
        rep.update(chunk)
    chunked = rep.finalize()
    trim = next(r for r in chunked if r.policy_name == "trim")
    trim_ref = next(o for o in ref.outcomes if o.name == "trim")
    assert trim.counterfactual.total_energy_j == trim_ref.counterfactual_energy_j
    assert trim.energy_saved_j > 0


# --------------------------------------------------------------------------- #
# Grid shape and family grouping
# --------------------------------------------------------------------------- #
def test_default_policy_grid_sizes():
    dense = default_policy_grid()
    assert len(dense) == 200
    assert len({tuple(sorted(p.describe().items())) for p in dense}) == 200
    legacy = default_policy_grid(dense=False)
    assert len(legacy) == 48
    assert len({tuple(sorted(p.describe().items())) for p in legacy}) == 48


def test_make_batches_groups_families_and_preserves_grid_order():
    dense = default_policy_grid()
    batches = make_batches(dense)
    # default thresholds everywhere: one batch per family
    assert [type(b).__name__ for b, _ in batches] == \
        ["NoOpBatch", "DownscaleBatch", "ParkingBatch", "PowerCapBatch"]
    flat = [i for _, idxs in batches for i in idxs]
    assert sorted(flat) == list(range(len(dense)))
    for batch, idxs in batches:
        assert idxs == sorted(idxs)          # grid order within each family
        assert len(batch.policies) == len(idxs)
    # distinct low-activity thresholds split a family into separate batches
    mixed = [DownscalePolicy(),
             DownscalePolicy(config=ControllerConfig(activity_threshold=0.03))]
    assert len(make_batches(mixed)) == 2

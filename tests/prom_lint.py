"""Prometheus text-exposition linter CLI over :func:`repro.obs.lint_exposition`.

CI runs the quick bench with ``--obs`` and lints the resulting exposition::

    PYTHONPATH=src python tests/prom_lint.py reports/obs_ci/metrics.prom \
        --require repro_backend_devices=4

``--require name=value`` additionally asserts that a sample with that exact
name (no labels) or any labelled variant of it equals ``value`` — used to pin
the device-count gauge in the forced-4-device CI lane. ``--require name``
(no ``=``) is presence-only: some sample of that name must exist, any value —
used for the degradation-ladder counters, whose values are zero on a clean
run but whose families must always be registered. Exit code 0 iff the
exposition parses and every requirement holds.
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys


def check_file(path: str, requirements: list[str]) -> list[str]:
    """Lint ``path``; returns all problems (empty list == clean)."""
    from repro.obs import lint_exposition

    text = pathlib.Path(path).read_text()
    problems = list(lint_exposition(text))

    for req in requirements:
        name, _, want = req.partition("=")
        pat = re.compile(rf"^{re.escape(name)}(?:\{{[^}}]*\}})? (.+)$",
                         re.MULTILINE)
        values = [float(m.group(1)) for m in pat.finditer(text)]
        if not values:
            problems.append(f"required metric {name!r} not found")
        elif not want:
            pass  # presence-only requirement: any value satisfies it
        elif not any(v == float(want) for v in values):
            problems.append(
                f"required {name}={want}, exposition has {values}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path", help="Prometheus text-exposition file")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME=VALUE",
                    help="assert a sample of NAME equals VALUE (repeatable)")
    args = ap.parse_args()

    problems = check_file(args.path, args.require)
    for p in problems:
        print(f"prom_lint: {p}", file=sys.stderr)
    if not problems:
        n = len({line.split("{")[0].split(" ")[0]
                 for line in pathlib.Path(args.path).read_text().splitlines()
                 if line and not line.startswith("#")})
        print(f"prom_lint: OK ({n} distinct sample names)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())

"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes + finite values; prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.models import api

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch_setup(request):
    cfg = get_smoke_config(request.param)
    params = api.init_params(KEY, cfg)
    return request.param, cfg, params


def test_full_configs_validate():
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        cfg.validate()
        n = api.count_params_abstract(cfg)
        assert n > 1e6, f"{arch}: suspiciously few params {n}"


def test_loss_and_grads_finite(arch_setup):
    arch, cfg, params = arch_setup
    batch = api.make_batch(cfg, 2, 32)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: api.loss_fn(p, b, cfg), has_aux=True)
    )(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


def test_train_step_reduces_loss(arch_setup):
    """A few SGD-ish steps on one repeated batch reduce the loss."""
    arch, cfg, params = arch_setup
    from repro.train.optimizer import adamw
    opt = adamw(lr=3e-3)
    state = opt.init(params)
    batch = api.make_batch(cfg, 2, 16)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: api.loss_fn(p, batch, cfg), has_aux=True)(params)
        params, state, _ = opt.step(params, grads, state)
        return params, state, loss

    losses = []
    for _ in range(5):
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{arch}: {losses}"


def test_prefill_decode_consistency(arch_setup):
    """Greedy decode after prefill matches teacher-forced next-token logits
    from a longer prefill (KV-cache correctness)."""
    arch, cfg, params = arch_setup
    batch = api.make_batch(cfg, 2, 17)
    tokens = batch["tokens"]
    kwargs = {k: batch[k] for k in ("frames", "vision") if k in batch}

    cache, logits_a = jax.jit(
        lambda p, t: api.prefill(p, t, cfg, **kwargs))(params, tokens[:, :16])
    cache = api.pad_cache(cfg, cache, 24)   # room for decoded tokens
    cache2, logits_b = jax.jit(
        lambda p, c, t: api.decode_step(p, c, t, cfg)
    )(params, cache, tokens[:, 16:17])
    # reference: prefill over all 17 tokens; its last logits must match the
    # decode-step logits (same inputs, cache path vs full path)
    _, logits_ref = jax.jit(
        lambda p, t: api.prefill(p, t, cfg, **kwargs))(params, tokens)
    np.testing.assert_allclose(
        np.asarray(logits_b[:, -1], np.float32),
        np.asarray(logits_ref[:, -1], np.float32),
        rtol=3e-2, atol=3e-2)
    assert int(cache2["len"]) == 17


def test_cache_shapes(arch_setup):
    arch, cfg, params = arch_setup
    cache = api.init_cache(cfg, batch=3, max_len=24)
    assert int(cache["len"]) == 0
    leaves = jax.tree.leaves(cache)
    assert all(np.isfinite(np.asarray(l, np.float32)).all() for l in leaves
               if hasattr(l, "shape"))

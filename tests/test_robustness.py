"""Dirty-telemetry robustness suite: hygiene, quarantine, fault injection.

Every test here runs the *production* code paths under deterministically
injected faults (:mod:`repro.testing.faults`): truncated and bit-flipped
shards, poisoned manifests, corrupt IR sidecars, processes killed
mid-write, and pool workers that crash or hang. The two load-bearing
contracts:

* **graceful degradation** — ``analyze_store`` / ``run_sweep`` /
  ``search_frontier`` complete without raising under ``strict=False`` with
  ~10% of shards corrupt and a crashing pool worker, quarantining exactly
  the injected shards and reporting ``coverage < 1``;
* **bit-identical degradation** — the surviving results equal the results
  of analyzing the clean subset directly, and a zero-fault run is
  bit-identical to the pre-hygiene pipeline.

Pool crash/hang tests fork real process pools and are gated behind
``REPRO_CHAOS=1`` (the CI chaos lane) to keep the default tier-1 run lean.
"""
import dataclasses
import os
import pathlib
import shutil
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

import repro.obs as obs
from repro.cluster import generate_cluster
from repro.telemetry import (FaultTolerance, HygieneContract, ShardReadError,
                             TelemetryStore, analyze_store, check_frame,
                             dcgm_to_frame, ingest_dcgm, ingest_frame,
                             scrub_store)
from repro.telemetry.hygiene import DEFAULT_CONTRACT, check_columns
from repro.telemetry.records import FIELDS, TelemetryFrame
from repro.testing import faults
from repro.whatif import (DownscalePolicy, IRConfig, NoOpPolicy,
                          frontier_from_dict, frontier_to_dict, get_ir,
                          run_sweep, search_frontier)
from repro.whatif import ir as ir_mod

chaos = pytest.mark.skipif(not os.environ.get("REPRO_CHAOS"),
                           reason="pool fault-injection lane; "
                                  "set REPRO_CHAOS=1 to run")

_GRID = [NoOpPolicy(), DownscalePolicy()]


def make_store(d, n_devices=4, horizon_s=900, seed=5, shard_s=300):
    store = TelemetryStore(d)
    generate_cluster(n_devices=n_devices, horizon_s=horizon_s, seed=seed,
                     store=store, shard_s=shard_s)
    return store


def clean_frame(n=60, power=120.0, job=3, t0=0.0):
    return TelemetryFrame({
        "timestamp": t0 + np.arange(n, dtype=np.float64),
        "hostname": np.zeros(n, np.int32),
        "device_id": np.zeros(n, np.int32),
        "platform": np.zeros(n, np.int32),
        "power": np.full(n, power),
        "sm": np.full(n, 50.0),
        "job_id": np.full(n, job, np.int64),
        "program_resident": np.ones(n, np.int8),
    })


def analysis_key(a):
    """Everything analysis produces except the robustness accounting —
    the payload that must be bit-identical across degradation paths."""
    return (a.fleet, a.unattributed_energy_j, a.n_intervals,
            [(j.job_id, j.duration_s, j.breakdown, tuple(j.intervals))
             for j in a.jobs])


def shard_path(store, entry):
    return store.root / entry["file"]


def clear_ir_caches():
    ir_mod._IR_CACHE.clear()
    ir_mod._IR_UNSUPPORTED.clear()


# --------------------------------------------------------------------------- #
# hygiene contract: check_frame / check_columns
# --------------------------------------------------------------------------- #
def test_clean_frame_passes_unchanged():
    f = clean_frame()
    out, v = check_frame(f)
    assert v.status == "ok" and not v.reasons and not v.repairs
    assert out is f                       # zero-fault path: same object


def test_repairs_are_subtractive_and_deterministic():
    f = clean_frame(n=40)
    cols = {k: v.copy() for k, v in f.columns.items()}
    cols["timestamp"][7] = np.nan         # clock step
    cols["power"][3] = -5.0               # glitched rail
    cols["power"][4] = 5000.0             # physically impossible
    dirty = TelemetryFrame(cols)
    out, v = check_frame(dirty)
    assert v.status == "repaired"
    assert v.repairs == {"nonfinite_timestamp": 1, "bad_power": 2}
    assert (v.rows_in, v.rows_out) == (40, 37)
    # deterministic: same bytes in, same verdict and same repaired rows
    out2, v2 = check_frame(TelemetryFrame({k: c.copy()
                                           for k, c in cols.items()}))
    assert v2 == v
    for k in out.columns:   # NaN-filled optional columns need equal_nan
        assert np.array_equal(out[k], out2[k],
                              equal_nan=out[k].dtype.kind == "f")
    # idempotent: a repaired frame is clean
    out3, v3 = check_frame(out)
    assert v3.status == "ok" and out3 is out


def test_duplicate_timestamps_keep_first():
    f = clean_frame(n=20)
    cols = {k: np.concatenate([v, v[:5]]) for k, v in f.columns.items()}
    cols["power"] = cols["power"].copy()
    cols["power"][20:] = 999.0            # replayed rows differ: must lose
    out, v = check_frame(TelemetryFrame(cols))
    assert v.repairs == {"duplicate_timestamp": 5}
    assert len(out) == 20
    assert np.array_equal(out["power"], f["power"])   # first-seen survives
    assert np.array_equal(out["timestamp"], f["timestamp"])  # input order


def test_garbage_shard_quarantined_not_repaired():
    f = clean_frame(n=30)
    cols = {k: v.copy() for k, v in f.columns.items()}
    cols["power"][:20] = np.nan           # 66% drop > max_repair_fraction
    out, v = check_frame(TelemetryFrame(cols))
    assert out is None and v.status == "quarantined"
    assert "excessive_repair" in v.reasons


def test_never_recorded_signal_quarantines():
    f = clean_frame(n=10)
    cols = dict(f.columns)
    cols["power"] = np.full(10, np.nan)
    out, v = check_frame(TelemetryFrame(cols))
    assert out is None and v.status == "quarantined"
    assert "missing_required:power" in v.reasons


def test_gaps_reported_never_filled():
    f = clean_frame(n=30)
    cols = {k: v.copy() for k, v in f.columns.items()}
    cols["timestamp"][15:] += 10_000.0    # one hole > max_gap_s
    out, v = check_frame(TelemetryFrame(cols))
    assert v.status == "ok" and len(out) == 30      # rows untouched
    assert v.reasons == ("gap_segments:1",)


def test_check_columns_contract():
    good = {f: np.zeros(3) for f in DEFAULT_CONTRACT.required_fields}
    assert check_columns(good).ok
    missing = dict(good)
    del missing["power"]
    v = check_columns(missing)
    assert v.status == "quarantined" and "missing_required:power" in v.reasons
    ragged = dict(good)
    ragged["power"] = np.zeros(2)
    assert "ragged_columns" in check_columns(ragged).reasons
    bad = dict(good)
    bad["power"] = np.array(["x", "y", "z"])
    assert any(r == "bad_dtype:power" for r in check_columns(bad).reasons)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_hygiene_idempotent_on_random_dirt(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 120))
    f = clean_frame(n=n)
    cols = {k: v.copy() for k, v in f.columns.items()}
    # sprinkle every dirt class the contract repairs
    for col, bad in (("timestamp", np.nan), ("power", -1.0), ("power", 1e6)):
        idx = rng.integers(0, n, size=rng.integers(0, max(1, n // 8)))
        cols[col][idx] = bad
    if rng.random() < 0.5:                # duplicated tail
        k = int(rng.integers(1, max(2, n // 4)))
        cols = {key: np.concatenate([c, c[:k]]) for key, c in cols.items()}
    out, v = check_frame(TelemetryFrame(cols))
    if v.status == "quarantined":
        assert out is None
        return
    assert v.rows_out == len(out) <= v.rows_in
    out2, v2 = check_frame(out)           # fixed point after one pass
    assert v2.status == "ok" and out2 is out


# --------------------------------------------------------------------------- #
# DCGM adapter
# --------------------------------------------------------------------------- #
def test_dcgm_adapter_scales_pads_and_synthesizes_time():
    frame = dcgm_to_frame({
        "DCGM_FI_DEV_POWER_USAGE": [100.0, 110.0, 120.0],
        "DCGM_FI_PROF_SM_ACTIVE": [0.5, 0.6],          # one missed sample
        "DCGM_FI_PROF_PCIE_TX_BYTES": [2e9, 2e9, 2e9],
        "DCGM_FI_SOME_FUTURE_FIELD": [1, 2, 3],        # unknown: ignored
    }, device_id=3, job_id=9)
    assert len(frame) == 3
    assert np.array_equal(frame["timestamp"], [0.0, 1.0, 2.0])
    assert np.array_equal(frame["power"], [100.0, 110.0, 120.0])
    assert frame["sm"][0] == 50.0 and np.isnan(frame["sm"][2])  # % + NaN pad
    assert np.allclose(frame["pcie_tx"], 2.0)                   # GB/s
    assert frame["device_id"][0] == 3 and frame["job_id"][0] == 9
    assert set(frame.columns) == set(FIELDS)


def test_ingest_dcgm_lands_a_hygiene_clean_shard():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        v = ingest_dcgm(store, {
            "DCGM_FI_DEV_POWER_USAGE": [100.0] * 30 + [-4.0],
            "DCGM_FI_PROF_SM_ACTIVE": [0.4] * 31,
        }, host="h0")
        assert v.status == "repaired" and v.repairs == {"bad_power": 1}
        assert store.total_rows == 30
        reread = TelemetryStore(d)
        _, rv = check_frame(reread.read_shard(
            reread.manifest["shards"][0]["file"]))
        assert rv.status == "ok"


def test_ingest_frame_refuses_garbage():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        f = clean_frame(n=10)
        cols = dict(f.columns)
        cols["power"] = np.full(10, np.nan)
        v = ingest_frame(store, TelemetryFrame(cols))
        assert v.status == "quarantined"
        assert store.total_rows == 0 and store.manifest["shards"] == []


# --------------------------------------------------------------------------- #
# scrub_store: whole-store sweep
# --------------------------------------------------------------------------- #
def test_scrub_store_repairs_quarantines_and_settles():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        store.write_shard(clean_frame(n=50), host="h0")
        dirty = {k: v.copy() for k, v in clean_frame(n=50, t0=100.0)
                 .columns.items()}
        dirty["power"][7] = -1.0
        store.write_shard(TelemetryFrame(dirty), host="h0")
        truncated = store.write_shard(clean_frame(n=50, t0=200.0), host="h0")
        faults.truncate_file(truncated)

        dry = scrub_store(TelemetryStore(d), dry_run=True)
        assert [v.status for v in dry] == ["ok", "repaired", "quarantined"]
        assert TelemetryStore(d).total_rows == 150     # dry run: untouched

        verdicts = scrub_store(TelemetryStore(d))
        assert [v.status for v in verdicts] == ["ok", "repaired",
                                                "quarantined"]
        after = TelemetryStore(d)
        assert after.total_rows == 99                  # 50 + 49 survive
        assert len(after.manifest["shards"]) == 2
        assert [q["reason"] for q in after.manifest["quarantine"]] \
            == ["corrupt"]
        assert (after.root / "quarantine" / truncated.name).exists()
        # settled: a second sweep is a no-op
        assert all(v.status == "ok" for v in scrub_store(after))
        # repaired shard re-reads clean under checksum verification
        for s in after.manifest["shards"]:
            after.read_shard(s["file"], verify=True)


# --------------------------------------------------------------------------- #
# storage: corruption detection, drift, recovery, atomicity
# --------------------------------------------------------------------------- #
def test_truncated_shard_raises_strict_and_skips_tolerant():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d)
        entry = store.manifest["shards"][1]
        faults.truncate_file(shard_path(store, entry))
        with pytest.raises(ShardReadError) as ei:
            store.read_shard(entry["file"])
        assert ei.value.reason == "corrupt"
        skips = []
        assert store.read_shard_or_skip(entry["file"], skips,
                                        strict=False) is None
        assert skips == [{"file": entry["file"], "host": entry["host"],
                          "rows": entry["rows"], "reason": "corrupt"}]


def test_bitflip_caught_by_checksum_verification():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        store.write_shard(clean_frame(n=200), host="h0")
        name = store.manifest["shards"][0]["file"]
        faults.bitflip_file(store.root / name / "power.npy", offset=180)
        fresh = TelemetryStore(d)
        fresh.read_shard(name)                         # plain read: no idea
        with pytest.raises(ShardReadError) as ei:
            fresh.read_shard(name, verify=True)        # checksummed read
        assert ei.value.reason == "checksum_mismatch"


def test_manifest_disk_drift_reported_as_verdicts():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d)
        assert store.verify_manifest() == []
        victim = store.manifest["shards"][0]["file"]
        (store.root / victim).unlink()
        stray = store.root / "telemetry_h9_d000_99999.npz"
        stray.write_bytes(b"not a shard")
        drift = {(r["file"], r["reason"]) for r in store.verify_manifest()}
        assert drift == {(victim, "missing_file"),
                         (stray.name, "orphan_file")}


def test_poisoned_manifest_recovers_by_rescan():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d)
        before = analysis_key(analyze_store(store, min_job_duration_s=300))
        n_shards = len(store.manifest["shards"])
        faults.poison_json(store.root / "manifest.json")
        recovered = TelemetryStore(d)
        assert recovered.manifest.get("recovered") is True
        assert len(recovered.manifest["shards"]) == n_shards
        after = analysis_key(analyze_store(recovered, min_job_duration_s=300))
        assert after == before


def test_kill_mid_write_never_tears_state():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d)
        baseline = analysis_key(analyze_store(store, min_job_duration_s=300))
        manifest_bytes = (store.root / "manifest.json").read_bytes()
        # every atomic commit path dies at the rename
        with faults.dying_renames():
            with pytest.raises(faults.SimulatedKill):
                store.write_shard(clean_frame(n=10), host="h0")
            with pytest.raises(faults.SimulatedKill):
                store.save_manifest()
            with pytest.raises(faults.SimulatedKill):
                ir_mod.save_sidecar(
                    ir_mod.build_ir(store, IRConfig()), store)
            with pytest.raises(faults.SimulatedKill):
                store.merge_manifest_key("run_ir", "deadbeef", {"file": "x"})
        survivor = TelemetryStore(d)
        assert (store.root / "manifest.json").read_bytes() == manifest_bytes
        assert analysis_key(analyze_store(
            survivor, min_job_duration_s=300)) == baseline
        assert survivor.verify_manifest() == []        # no half-written shard


# --------------------------------------------------------------------------- #
# quarantine == clean subset (the acceptance bit-identity)
# --------------------------------------------------------------------------- #
def _dirty_and_clean_pair(d, seed=17):
    """One corpus twice: `dirty` has ~10% of shards truncated on disk,
    `clean` has exactly those shards quarantined away. Returns
    (dirty_store, clean_store, corrupted_names)."""
    d = pathlib.Path(d)
    dirty_dir, clean_dir = d / "dirty", d / "clean"
    make_store(dirty_dir, n_devices=8, seed=seed, shard_s=300)
    shutil.copytree(dirty_dir, clean_dir)
    dirty = TelemetryStore(dirty_dir)
    names = [s["file"] for s in dirty.manifest["shards"]]
    k = max(2, round(0.1 * len(names)))
    victims = names[1:: max(1, len(names) // k)][:k]
    clean = TelemetryStore(clean_dir)
    for name in victims:
        faults.truncate_file(shard_path(dirty, {"file": name}))
        clean.quarantine_shard(name, "corrupt", flush_manifest=False)
    clean.save_manifest()
    return dirty, clean, victims


def test_analyze_skips_quarantined_and_matches_clean_subset():
    with tempfile.TemporaryDirectory() as d:
        dirty, clean, victims = _dirty_and_clean_pair(d)
        assert len(victims) >= 2
        got = analyze_store(dirty, min_job_duration_s=300, strict=False)
        want = analyze_store(clean, min_job_duration_s=300)
        assert analysis_key(got) == analysis_key(want)
        assert sorted(s["file"] for s in got.skipped) == sorted(victims)
        assert 0.0 < got.coverage < 1.0
        lost = sum(s["rows"] for s in got.skipped)
        assert got.coverage == pytest.approx(
            1.0 - lost / dirty.rows_on_disk())
        assert want.coverage == 1.0 and want.skipped == ()
        # strict mode still refuses the dirty store loudly
        with pytest.raises(ShardReadError):
            analyze_store(dirty, min_job_duration_s=300)


def test_sweep_and_search_survive_dirty_store_bit_identically():
    with tempfile.TemporaryDirectory() as d:
        dirty, clean, victims = _dirty_and_clean_pair(d, seed=23)
        clear_ir_caches()
        got = run_sweep(dirty, _GRID, min_job_duration_s=300, strict=False)
        clear_ir_caches()
        want = run_sweep(clean, _GRID, min_job_duration_s=300)
        assert got.outcomes == want.outcomes
        assert 0.0 < got.coverage < 1.0 and want.coverage == 1.0
        from repro.whatif import default_families
        fams = [f for f in default_families(composites=False)
                if f.name == "powercap"]
        clear_ir_caches()
        sgot = search_frontier(dirty, families=fams, max_evals=6,
                               min_job_duration_s=300, strict=False)
        clear_ir_caches()
        swant = search_frontier(clean, families=fams, max_evals=6,
                                min_job_duration_s=300)
        assert sgot.frontier.outcomes == swant.frontier.outcomes
        assert sgot.frontier.coverage < 1.0
        assert swant.frontier.coverage == 1.0


def test_zero_faults_identical_to_strict_path():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d, seed=29)
        strict = run_sweep(store, _GRID, min_job_duration_s=300)
        clear_ir_caches()
        tolerant = run_sweep(store, _GRID, min_job_duration_s=300,
                             strict=False, verify=True,
                             fault=FaultTolerance())
        assert frontier_to_dict(strict) == frontier_to_dict(tolerant)
        assert tolerant.coverage == 1.0


def test_frontier_coverage_serializes_and_defaults():
    with tempfile.TemporaryDirectory() as d:
        dirty, _, _ = _dirty_and_clean_pair(d, seed=31)
        f = run_sweep(dirty, _GRID, min_job_duration_s=300, strict=False)
        payload = frontier_to_dict(f)
        assert payload["coverage"] == f.coverage < 1.0
        assert frontier_from_dict(payload).coverage == f.coverage
        legacy = dict(payload)
        del legacy["coverage"]                 # pre-robustness payloads
        assert frontier_from_dict(legacy).coverage == 1.0


# --------------------------------------------------------------------------- #
# IR sidecar corruption -> rebuild
# --------------------------------------------------------------------------- #
def test_corrupt_sidecar_rebuilds_transparently():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d, seed=7)
        cfg = IRConfig()
        built = get_ir(store, cfg)             # builds + persists sidecar
        sidecar = store.root / ir_mod.sidecar_name(cfg)
        assert sidecar.exists()
        faults.truncate_file(sidecar)
        clear_ir_caches()
        reloaded = get_ir(TelemetryStore(d), cfg)   # rebuild, not a crash
        assert reloaded.source_rows == built.source_rows
        assert sorted(reloaded.streams) == sorted(built.streams)
        assert sidecar.exists()                # persisted a fresh one
        clear_ir_caches()
        assert ir_mod.load_sidecar(TelemetryStore(d), cfg) is not None


def test_poisoned_ir_manifest_entry_rebuilds():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(d, seed=7)
        cfg = IRConfig()
        get_ir(store, cfg)
        fresh = TelemetryStore(d)
        fresh.manifest[ir_mod.MANIFEST_KEY] = {"oops": "not-a-dict-entry"}
        clear_ir_caches()
        assert ir_mod.load_sidecar(fresh, cfg) is None
        ir = get_ir(fresh, cfg)                # falls through to a build
        assert ir.source_rows == fresh.total_rows


# --------------------------------------------------------------------------- #
# pool fault supervisor (chaos lane)
# --------------------------------------------------------------------------- #
@chaos
def test_crashing_worker_is_retried_to_the_same_answer():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(pathlib.Path(d) / "store", n_devices=8, seed=13)
        want = analysis_key(analyze_store(store, min_job_duration_s=300,
                                          compact=False))
        tol = FaultTolerance(max_retries=2, backoff_s=0.01)
        with faults.plan(pathlib.Path(d) / "plan", crash=("analyze",)):
            got = analyze_store(store, min_job_duration_s=300, workers=2,
                                fault=tol, compact=False)
        assert analysis_key(got) == want and got.coverage == 1.0


@chaos
def test_hung_worker_times_out_and_retries():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(pathlib.Path(d) / "store", n_devices=8, seed=13)
        want = analysis_key(analyze_store(store, min_job_duration_s=300,
                                          compact=False))
        tol = FaultTolerance(max_retries=1, timeout_s=2.0, backoff_s=0.01)
        with faults.plan(pathlib.Path(d) / "plan", hang=("analyze",),
                         hang_s=60.0):
            got = analyze_store(store, min_job_duration_s=300, workers=2,
                                fault=tol, compact=False)
        assert analysis_key(got) == want


@chaos
def test_exhausted_retries_degrade_to_in_process():
    with tempfile.TemporaryDirectory() as d:
        store = make_store(pathlib.Path(d) / "store", n_devices=8, seed=13)
        want = analysis_key(analyze_store(store, min_job_duration_s=300,
                                          compact=False))
        obs.enable()
        try:
            obs.reset()
            with faults.plan(pathlib.Path(d) / "plan", crash=("analyze",)):
                got = analyze_store(store, min_job_duration_s=300, workers=2,
                                    fault=FaultTolerance(max_retries=0,
                                                         backoff_s=0.01),
                                    compact=False)
            text = obs.render_prometheus()
        finally:
            obs.disable()
            obs.reset()
        assert analysis_key(got) == want      # parent redid the lost work
        assert 'repro_fallbacks_total{from="pool"' in text
        assert "repro_partition_retries_total" in text


@chaos
def test_sweep_survives_crashing_worker_and_corrupt_shards_together():
    """The acceptance scenario: ~10% corrupt shards AND a crashing pool
    worker in the same run — completes, quarantines exactly the injected
    shards, and matches the clean subset bit-for-bit."""
    with tempfile.TemporaryDirectory() as d:
        dirty, clean, victims = _dirty_and_clean_pair(
            pathlib.Path(d) / "pair", seed=37)
        clear_ir_caches()
        want = run_sweep(clean, _GRID, min_job_duration_s=300)
        clear_ir_caches()
        with faults.plan(pathlib.Path(d) / "plan", crash=("replay_ir",)):
            got = run_sweep(dirty, _GRID, min_job_duration_s=300, workers=2,
                            strict=False,
                            fault=FaultTolerance(max_retries=2,
                                                 backoff_s=0.01))
        assert got.outcomes == want.outcomes
        assert got.coverage < 1.0


# --------------------------------------------------------------------------- #
# observability families
# --------------------------------------------------------------------------- #
def test_degradation_families_registered_and_lintable(tmp_path):
    obs.enable()
    try:
        obs.reset()
        obs.init_degradation_metrics()
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    for name, _, _ in obs.DEGRADATION_FAMILIES:
        assert f"\n{name} " in text or text.startswith(f"{name} ")
    assert obs.lint_exposition(text) == []
    # the CI chaos lane lints presence-only (--require NAME, no value)
    prom = tmp_path / "metrics.prom"
    prom.write_text(text)
    import prom_lint
    assert prom_lint.check_file(str(prom), [
        "repro_fallbacks_total", "repro_shards_quarantined_total",
        "repro_shards_repaired_total", "repro_partition_retries_total",
        "repro_coverage_fraction"]) == []
    assert prom_lint.check_file(str(prom), ["repro_not_a_metric"]) != []


def test_quarantine_counters_emitted():
    with tempfile.TemporaryDirectory() as d:
        dirty, _, victims = _dirty_and_clean_pair(d, seed=41)
        obs.enable()
        try:
            obs.reset()
            analyze_store(dirty, min_job_duration_s=300, strict=False,
                          compact=False)
            text = obs.render_prometheus()
        finally:
            obs.disable()
            obs.reset()
        assert f'repro_shards_quarantined_total{{reason="corrupt"}} ' \
            f'{len(victims)}' in text
        assert 'repro_coverage_fraction{stage="analyze"}' in text

"""Live-controller suite: tick loop, crash/resume bit-identity, degradation.

The load-bearing contract (ISSUE 10): ``kill -9`` at *any* tick-phase
boundary — post-ingest/pre-extend, post-extend/pre-checkpoint,
mid-checkpoint-write — followed by a restart from the checkpoint converges
to a frontier **bit-identical** to an uninterrupted run over the same
shard sequence. The in-process property test walks every boundary by
patching :func:`repro.live.checkpoint.fault_hook`; the chaos-gated test
does it for real with a fire-once ``os._exit`` plan in a child process
(``REPRO_CHAOS=1``, the CI chaos lane).

Degradation is tested with the PR 8 corruptors: a corrupt checkpoint
cold-starts (``repro_fallbacks_total{reason="checkpoint_corrupt"}``), a
clock-skewed shard (byte-valid, semantically poisoned) exhausts the ladder
and serves the stale knee with the watermark held, and an unreadable shard
is skipped with coverage accounting — never an exception.
"""
import json
import os
import pathlib
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import repro.obs as obs
from repro.live import (Checkpoint, DcgmDirectoryProducer, LiveConfig,
                        LiveController, Rung, SimulatorProducer,
                        SyntheticProducer, TickSupervisor, ladder,
                        load_checkpoint, parse_power_json, remove_checkpoint,
                        save_checkpoint, watermark_valid)
from repro.live import checkpoint as checkpoint_mod
from repro.live import controller as controller_mod
from repro.live.checkpoint import MID_CHECKPOINT_STAGE
from repro.live.controller import PRE_CHECKPOINT_STAGE, PRE_EXTEND_STAGE
from repro.telemetry import FaultTolerance, TelemetryStore, analyze_store
from repro.telemetry.storage import MANIFEST_NAME
from repro.testing import faults
from repro.whatif import frontier_to_dict
from repro.whatif import ir as ir_mod
from repro.whatif.search import default_families

chaos = pytest.mark.skipif(not os.environ.get("REPRO_CHAOS"),
                           reason="kill -9 crash/resume lane; "
                                  "set REPRO_CHAOS=1 to run")

#: shard sequence every crash/resume scenario replays
N_WINDOWS = 3
PRODUCER_KW = dict(n_streams=16, window_s=30, dt_s=5.0, seed=3)


def clear_ir_caches():
    ir_mod._IR_CACHE.clear()
    ir_mod._IR_UNSUPPORTED.clear()


def fast_families():
    return [f for f in default_families(composites=False)
            if f.name == "downscale"]


def fast_cfg(**kw):
    sk = {"max_rounds": 1, "families": fast_families()}
    sk.update(kw.pop("search_kwargs", {}))
    kw.setdefault("max_evals", 16)
    return LiveConfig(search_kwargs=sk, **kw)


def fkey(frontier):
    """The bit-identity witness: canonical JSON of the frontier codec."""
    return json.dumps(frontier_to_dict(frontier), sort_keys=True)


def drive(root, ckpt_path, n_windows, cfg=None, producer_kw=None):
    """The daemon loop in test form: drain pending shards before the next
    append (a restart ticks through the backlog it crashed on before new
    windows land, preserving the per-tick shard grouping), append windows
    until ``n_windows`` have been emitted, stop when drained.

    Creating the store/producer/controller fresh on every call *is* the
    restart: the producer resumes from the manifest's shard count (its
    windows are deterministic per ``(seed, window)``), the controller from
    the checkpoint."""
    store = TelemetryStore(root)
    prod = SyntheticProducer(store, **(producer_kw or PRODUCER_KW))
    prod.window = len(store.manifest["shards"])
    ctrl = LiveController(store, ckpt_path, cfg or fast_cfg())
    for _ in range(20 * n_windows + 20):
        store.refresh()
        if store.shards_since(ctrl.n_shards):
            ctrl.tick()
        elif prod.window < n_windows:
            prod.step()
        else:
            return ctrl
    raise AssertionError("driver did not drain — controller wedged?")


class SimCrash(RuntimeError):
    """In-process stand-in for kill -9 at a tick-phase boundary."""


def arm_crash(monkeypatch, stage, skip=0):
    """Patch the fault hook to raise once at the ``skip``-th occurrence of
    ``stage`` (each tick passes each boundary once, so ``skip`` == the
    crashing tick index). Both namespaces are patched: the controller
    imported the name, ``save_checkpoint`` calls its own module's."""
    state = {"remaining": skip, "fired": False}

    def hook(s):
        if s != stage or state["fired"]:
            return
        if state["remaining"] > 0:
            state["remaining"] -= 1
            return
        state["fired"] = True
        raise SimCrash(s)

    monkeypatch.setattr(controller_mod, "fault_hook", hook)
    monkeypatch.setattr(checkpoint_mod, "fault_hook", hook)
    return state


# --------------------------------------------------------------------------- #
# storage: O(1) polling (satellite 1)
# --------------------------------------------------------------------------- #
def make_frame(n=10, t0=0.0, job=1):
    from repro.telemetry.records import TelemetryFrame
    return TelemetryFrame({
        "timestamp": t0 + np.arange(n, dtype=np.float64),
        "hostname": np.zeros(n, np.int32),
        "device_id": np.zeros(n, np.int32),
        "platform": np.zeros(n, np.int32),
        "power": np.full(n, 120.0),
        "sm": np.full(n, 50.0),
        "job_id": np.full(n, job, np.int64),
        "program_resident": np.ones(n, np.int8),
    })


def test_generation_counts_shard_mutations(tmp_path):
    store = TelemetryStore(tmp_path / "s")
    assert store.generation == 0
    store.append(make_frame(t0=0.0), host="h0")
    g1 = store.generation
    store.append(make_frame(t0=100.0), host="h0")
    g2 = store.generation
    assert g2 > g1 > 0
    name = store.manifest["shards"][-1]["file"]
    store.quarantine_shard(name, "test")
    store.save_manifest()
    assert store.generation > g2


def test_shards_since_slices_the_suffix(tmp_path):
    store = TelemetryStore(tmp_path / "s")
    for i in range(3):
        store.append(make_frame(t0=100.0 * i), host="h0")
    assert len(store.shards_since(0)) == 3
    suffix = store.shards_since(2)
    assert [s["file"] for s in suffix] == \
        [store.manifest["shards"][2]["file"]]
    assert store.shards_since(3) == []
    with pytest.raises(ValueError):
        store.shards_since(-1)


def test_refresh_adopts_concurrent_appends(tmp_path):
    reader = TelemetryStore(tmp_path / "s")
    writer = TelemetryStore(tmp_path / "s")
    assert reader.refresh() is False          # nothing changed
    writer.append(make_frame(), host="h0")
    assert reader.refresh() is True
    assert len(reader.manifest["shards"]) == 1
    assert reader.generation == writer.generation
    assert reader.refresh() is False          # idempotent


def test_refresh_keeps_snapshot_on_torn_manifest(tmp_path):
    store = TelemetryStore(tmp_path / "s")
    store.append(make_frame(), host="h0")
    snapshot = json.dumps(store.manifest, sort_keys=True)
    manifest = tmp_path / "s" / MANIFEST_NAME
    manifest.write_text('{"shards": [{"file": "tele')   # mid-write read
    assert store.refresh() is False
    assert json.dumps(store.manifest, sort_keys=True) == snapshot


# --------------------------------------------------------------------------- #
# controller: tick loop
# --------------------------------------------------------------------------- #
def test_tick_idle_refreshed_and_published(tmp_path):
    store = TelemetryStore(tmp_path / "store")
    prod = SyntheticProducer(store, **PRODUCER_KW)
    pub = tmp_path / "knee.json"
    ctrl = LiveController(store, tmp_path / "ckpt.json", fast_cfg(),
                          publish_path=pub)
    r = ctrl.tick()
    assert r.result == "idle" and not pub.exists()
    prod.step()
    r = ctrl.tick()
    assert r.result == "refreshed" and r.rung == "warm_numpy"
    assert r.n_new_shards == 1 and r.coalesced == 0
    assert r.knee is not None and r.staleness_s >= 0
    assert ctrl.n_shards == 1
    published = json.loads(pub.read_text())
    assert published["stale"] is False and published["tick"] == 1
    ckpt = load_checkpoint(tmp_path / "ckpt.json", store)
    assert ckpt.tick == 1 and ckpt.n_shards == 1
    assert ckpt.frontier is not None


def test_tick_coalesces_backlog_into_one_extend(tmp_path):
    store = TelemetryStore(tmp_path / "store")
    prod = SyntheticProducer(store, **PRODUCER_KW)
    ctrl = LiveController(store, tmp_path / "ckpt.json", fast_cfg())
    for _ in range(3):
        prod.step()
    r = ctrl.tick()
    assert r.result == "refreshed"
    assert r.n_new_shards == 3 and r.coalesced == 2
    assert ctrl.n_shards == 3
    assert ctrl.tick_no == 1                  # one tick covered the backlog


def test_run_drains_then_stops_when_idle(tmp_path):
    store = TelemetryStore(tmp_path / "store")
    prod = SyntheticProducer(store, **PRODUCER_KW)
    prod.step()
    ctrl = LiveController(store, tmp_path / "ckpt.json", fast_cfg())
    results = ctrl.run(max_ticks=5, stop_when_idle=True)
    assert [r.result for r in results] == ["refreshed", "idle"]


def test_publish_is_idempotent_across_restart(tmp_path):
    store = TelemetryStore(tmp_path / "store")
    prod = SyntheticProducer(store, **PRODUCER_KW)
    prod.step()
    pub = tmp_path / "knee.json"
    ctrl = LiveController(store, tmp_path / "ckpt.json", fast_cfg(),
                          publish_path=pub)
    ctrl.tick()
    published = pub.read_text()
    pub.unlink()                    # crash between checkpoint and publish
    LiveController(store, tmp_path / "ckpt.json", fast_cfg(),
                   publish_path=pub)
    assert pub.read_text() == published


# --------------------------------------------------------------------------- #
# crash/resume bit-identity (the tentpole property, satellite 3)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def baseline_key(tmp_path_factory):
    """The uninterrupted run's frontier over the canonical shard sequence."""
    root = tmp_path_factory.mktemp("baseline")
    clear_ir_caches()
    ctrl = drive(root / "store", root / "ckpt.json", N_WINDOWS)
    assert ctrl.frontier is not None and ctrl.tick_no == N_WINDOWS
    return fkey(ctrl.frontier)


def test_restart_every_tick_is_bit_identical(tmp_path, baseline_key):
    """A controller rebuilt from its checkpoint after *every* tick (the
    crash-after-commit case: the restart state is the new checkpoint)
    converges to the uninterrupted frontier."""
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    prod = SyntheticProducer(store, **PRODUCER_KW)
    for _ in range(N_WINDOWS):
        prod.step()
        clear_ir_caches()
        ctrl = drive(root, ckpt, n_windows=0)   # fresh controller each time
    assert ctrl.tick_no == N_WINDOWS
    assert fkey(ctrl.frontier) == baseline_key


@pytest.mark.parametrize("crash_tick", [0, 1])
@pytest.mark.parametrize("stage", [PRE_EXTEND_STAGE, PRE_CHECKPOINT_STAGE,
                                   MID_CHECKPOINT_STAGE])
def test_crash_at_any_boundary_resumes_bit_identical(
        tmp_path, monkeypatch, baseline_key, stage, crash_tick):
    """Crash at every tick-phase boundary × tick index; the restarted run's
    final frontier equals the uninterrupted baseline byte for byte."""
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    clear_ir_caches()
    state = arm_crash(monkeypatch, stage, skip=crash_tick)
    with pytest.raises(SimCrash):
        drive(root, ckpt, N_WINDOWS)
    assert state["fired"]
    monkeypatch.undo()              # the "process" died; restart clean
    clear_ir_caches()               # a real restart has cold IR caches
    ctrl = drive(root, ckpt, N_WINDOWS)
    assert ctrl.tick_no == N_WINDOWS
    assert ctrl.n_shards == N_WINDOWS
    assert fkey(ctrl.frontier) == baseline_key


@chaos
@pytest.mark.parametrize("stage", [PRE_EXTEND_STAGE, PRE_CHECKPOINT_STAGE,
                                   MID_CHECKPOINT_STAGE])
def test_kill9_child_resumes_bit_identical(tmp_path, stage):
    """The real thing: a child driver process is killed by a fire-once
    ``os._exit(13)`` plan at the given boundary, relaunched, and must
    converge to the clean baseline's frontier."""
    child = (
        "import json, pathlib, sys\n"
        "from repro.telemetry.storage import TelemetryStore\n"
        "from repro.live import LiveController, LiveConfig, "
        "SyntheticProducer\n"
        "from repro.whatif.report import frontier_to_dict\n"
        "from repro.whatif.search import default_families\n"
        "root, ckpt, out, n_windows = (sys.argv[1], sys.argv[2], "
        "sys.argv[3], int(sys.argv[4]))\n"
        f"producer_kw = {PRODUCER_KW!r}\n"
        "store = TelemetryStore(root)\n"
        "prod = SyntheticProducer(store, **producer_kw)\n"
        "prod.window = len(store.manifest['shards'])\n"
        "fams = [f for f in default_families(composites=False) "
        "if f.name == 'downscale']\n"
        "cfg = LiveConfig(max_evals=16, "
        "search_kwargs={'max_rounds': 1, 'families': fams})\n"
        "ctrl = LiveController(store, ckpt, cfg)\n"
        "for _ in range(20 * n_windows + 20):\n"
        "    store.refresh()\n"
        "    if store.shards_since(ctrl.n_shards):\n"
        "        ctrl.tick()\n"
        "    elif prod.window < n_windows:\n"
        "        prod.step()\n"
        "    else:\n"
        "        break\n"
        "else:\n"
        "    sys.exit(2)\n"
        "pathlib.Path(out).write_text(json.dumps("
        "frontier_to_dict(ctrl.frontier), sort_keys=True))\n"
    )

    def launch(root, ckpt, out):
        return subprocess.run(
            [sys.executable, "-c", child, str(root), str(ckpt), str(out),
             str(N_WINDOWS)],
            env=os.environ.copy(), timeout=600).returncode

    # clean baseline first, before any plan lands in the environment
    base_out = tmp_path / "baseline.json"
    assert launch(tmp_path / "base_store", tmp_path / "base_ckpt.json",
                  base_out) == 0

    out = tmp_path / "frontier.json"
    with faults.plan(tmp_path / "plan", crash=[stage]):
        rc = launch(tmp_path / "store", tmp_path / "ckpt.json", out)
        assert rc == faults.CRASH_EXIT_CODE     # died at the boundary
        assert not out.exists()
        rc = launch(tmp_path / "store", tmp_path / "ckpt.json", out)
        assert rc == 0                          # fire-once: restart is clean
    assert out.read_text() == base_out.read_text()


# --------------------------------------------------------------------------- #
# checkpoint: atomicity + tolerant restore (satellite 2)
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    path = tmp_path / "ckpt.json"
    ckpt = Checkpoint(tick=4, n_shards=7, source_rows=9000, generation=11,
                      frontier={"schema_version": 1, "outcomes": []})
    save_checkpoint(ckpt, path)
    assert load_checkpoint(path) == ckpt
    remove_checkpoint(path)
    assert load_checkpoint(path) is None


def test_checkpoint_commit_is_atomic(tmp_path):
    path = tmp_path / "ckpt.json"
    first = Checkpoint(tick=1, n_shards=1, source_rows=10, generation=1,
                       frontier=None)
    save_checkpoint(first, path)
    with faults.dying_renames():
        with pytest.raises(faults.SimulatedKill):
            save_checkpoint(Checkpoint(tick=2, n_shards=2, source_rows=20,
                                       generation=2, frontier=None), path)
    assert load_checkpoint(path) == first       # destination untouched
    assert path.with_name(path.name + ".tmp").exists()  # orphaned temp
    remove_checkpoint(path)
    assert not path.with_name(path.name + ".tmp").exists()


@pytest.mark.parametrize("mode", ["truncate", "poison"])
def test_corrupt_checkpoint_cold_starts_never_crashes(tmp_path, mode):
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    prod = SyntheticProducer(store, **PRODUCER_KW)
    prod.step()
    LiveController(store, ckpt, fast_cfg()).tick()
    faults.corrupt_checkpoint(ckpt, mode=mode)
    obs.enable()
    try:
        obs.reset()
        ctrl = LiveController(store, ckpt, fast_cfg())
        assert ctrl.tick_no == 0 and ctrl.frontier is None  # cold start
        r = ctrl.tick()                  # and the loop still works
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert r.result == "refreshed" and ctrl.n_shards == 1
    assert ('repro_fallbacks_total{from="checkpoint",'
            'reason="checkpoint_corrupt",to="cold_start"} 1') in text
    assert "repro_live_checkpoint_corrupt_total" in text


def test_bitflipped_checkpoint_never_crashes(tmp_path):
    """A single flipped bit may stay parseable JSON — the contract is only
    'never crash, resume or cold-start': the controller must construct."""
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    SyntheticProducer(store, **PRODUCER_KW).step()
    LiveController(store, ckpt, fast_cfg()).tick()
    faults.corrupt_checkpoint(ckpt, mode="bitflip")
    ctrl = LiveController(store, ckpt, fast_cfg())
    assert ctrl.tick().result in ("refreshed", "idle")


def test_broken_watermark_cold_starts(tmp_path):
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    SyntheticProducer(store, **PRODUCER_KW).step()
    rows = store.total_rows
    save_checkpoint(Checkpoint(tick=3, n_shards=1, source_rows=rows + 1,
                               generation=1, frontier=None), ckpt)
    assert not watermark_valid(load_checkpoint(ckpt), store)
    obs.enable()
    try:
        obs.reset()
        ctrl = LiveController(store, ckpt, fast_cfg())
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert ctrl.tick_no == 0
    assert ('repro_fallbacks_total{from="checkpoint",'
            'reason="watermark_broken",to="cold_start"} 1') in text


# --------------------------------------------------------------------------- #
# degradation: poisoned + unreadable shards
# --------------------------------------------------------------------------- #
def test_skewed_shard_serves_stale_knee_and_holds_watermark(tmp_path):
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    prod = SyntheticProducer(store, **PRODUCER_KW)
    prod.step()
    cfg = fast_cfg(fault=FaultTolerance(max_retries=0, timeout_s=None,
                                        backoff_s=0.0))
    ctrl = LiveController(store, ckpt, cfg)
    assert ctrl.tick().result == "refreshed"
    good_key = fkey(ctrl.frontier)
    prod.step()
    # byte-valid shard, clock stepped back an hour: per-stream ordering
    # is violated across shards, poisoning both the IR and row paths
    faults.skew_shard(store, store.manifest["shards"][-1]["file"])
    obs.enable()
    try:
        obs.reset()
        clear_ir_caches()
        r = ctrl.tick()
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert r.result == "stale" and r.stale
    assert r.knee is not None                    # previous knee still served
    assert fkey(ctrl.frontier) == good_key       # frontier unchanged
    assert ctrl.n_shards == 1                    # watermark held: stays pending
    assert 'to="stale_knee"' in text
    assert 'repro_live_ticks_total{result="stale"} 1' in text
    ckpt_state = load_checkpoint(ckpt, store)
    assert ckpt_state.n_shards == 1              # checkpoint not advanced


def test_unreadable_shard_skipped_with_coverage(tmp_path):
    root, ckpt = tmp_path / "store", tmp_path / "ckpt.json"
    store = TelemetryStore(root)
    prod = SyntheticProducer(store, **PRODUCER_KW)
    prod.step()
    ctrl = LiveController(store, ckpt, fast_cfg())
    assert ctrl.tick().result == "refreshed"
    prod.step()
    faults.truncate_file(root / store.manifest["shards"][-1]["file"])
    clear_ir_caches()
    r = ctrl.tick()                  # strict=False: skip, account, proceed
    assert r.result == "refreshed"
    assert r.coverage < 1.0
    assert ctrl.n_shards == 2        # watermark advances past the skip


# --------------------------------------------------------------------------- #
# supervisor: retry, ladder, deadline
# --------------------------------------------------------------------------- #
def test_ladder_shapes():
    assert [r.name for r in ladder("numpy")] == ["warm_numpy", "cold_numpy"]
    assert [r.name for r in ladder("jax")] == \
        ["warm_jax", "warm_numpy", "cold_numpy"]
    assert ladder("jax")[0] == Rung("warm_jax", "jax", True)
    with pytest.raises(ValueError):
        TickSupervisor(rungs=[])


def test_supervisor_first_rung_success():
    sup = TickSupervisor(backend="numpy")
    res, rung, err = sup.run(lambda rung: rung.name)
    assert (res, rung.name, err) == ("warm_numpy", "warm_numpy", None)


def test_supervisor_retries_then_descends_ladder():
    calls = []

    def attempt(rung):
        calls.append(rung.name)
        if rung.warm:
            raise RuntimeError("warm poisoned")
        return "cold ok"

    fault = FaultTolerance(max_retries=1, timeout_s=None, backoff_s=0.0)
    obs.enable()
    try:
        obs.reset()
        sup = TickSupervisor(fault, backend="jax")
        res, rung, err = sup.run(attempt)
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert res == "cold ok" and rung.name == "cold_numpy" and err is None
    # each failing rung attempted max_retries + 1 times
    assert calls == ["warm_jax", "warm_jax", "warm_numpy", "warm_numpy",
                     "cold_numpy"]
    assert "repro_live_tick_retries_total 2" in text
    assert ('repro_fallbacks_total{from="warm_jax",'
            'reason="RuntimeError",to="warm_numpy"} 1') in text
    assert 'from="warm_numpy",reason="RuntimeError",to="cold_numpy"' in text


def test_supervisor_exhausted_returns_last_error():
    boom = ValueError("all rungs poisoned")

    def attempt(rung):
        raise boom

    fault = FaultTolerance(max_retries=0, timeout_s=None, backoff_s=0.0)
    res, rung, err = TickSupervisor(fault, backend="numpy").run(attempt)
    assert res is None and rung is None and err is boom


def test_supervisor_deadline_abandons_hung_attempt():
    import time

    def attempt(rung):
        time.sleep(30)

    fault = FaultTolerance(max_retries=3, timeout_s=0.3, backoff_s=0.0)
    obs.enable()
    try:
        obs.reset()
        res, rung, err = TickSupervisor(fault, backend="numpy").run(attempt)
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert (res, rung, err) == (None, None, None)   # err None -> "deadline"
    assert "repro_live_deadline_misses_total 1" in text


def test_supervisor_threaded_path_still_succeeds():
    def attempt(rung):
        if rung.warm:
            raise RuntimeError("warm fails fast")
        return 42

    fault = FaultTolerance(max_retries=0, timeout_s=30.0, backoff_s=0.0)
    res, rung, err = TickSupervisor(fault, backend="numpy").run(attempt)
    assert res == 42 and rung.name == "cold_numpy" and err is None


# --------------------------------------------------------------------------- #
# producers (satellite coverage for the feeds)
# --------------------------------------------------------------------------- #
def test_simulator_producer_matches_one_shot_emission(tmp_path):
    from repro.cluster import generate_cluster
    kw = dict(n_devices=4, horizon_s=900, seed=5, min_job_s=300)
    one_shot = TelemetryStore(tmp_path / "one_shot")
    generate_cluster(store=one_shot, shard_s=300, **kw)
    drip = TelemetryStore(tmp_path / "drip")
    prod = SimulatorProducer(drip, window_s=300,
                             n_devices=4, horizon_s=900, seed=5,
                             min_job_s=300)
    total = 0
    while not prod.exhausted:
        total += prod.step()
    assert total == one_shot.total_rows == drip.total_rows
    a = analyze_store(one_shot, min_job_duration_s=300, compact=False)
    b = analyze_store(drip, min_job_duration_s=300, compact=False)
    assert a.fleet == b.fleet
    assert {j.job_id: j.breakdown for j in a.jobs} == \
        {j.job_id: j.breakdown for j in b.jobs}


def test_synthetic_producer_deterministic(tmp_path):
    stores = []
    for name in ("a", "b"):
        store = TelemetryStore(tmp_path / name)
        prod = SyntheticProducer(store, **PRODUCER_KW)
        prod.step()
        prod.step()
        stores.append(store)
    rows = [[(s["file"], s["rows"], s["sha256"])
             for s in st.manifest["shards"]] for st in stores]
    assert rows[0] == rows[1]        # byte-identical shard sequences


def test_dcgm_directory_producer_both_layouts(tmp_path):
    dumps = tmp_path / "dumps"
    dumps.mkdir()
    n = 30
    (dumps / "a_dcgm.json").write_text(json.dumps({
        "DCGM_FI_DEV_POWER_USAGE": [150.0 + i for i in range(n)],
        "DCGM_FI_PROF_SM_ACTIVE": [0.5] * n,
        "timestamp": list(range(n)),
        "device_id": 0,
    }))
    (dumps / "b_power.json").write_text(json.dumps({
        "samples": [{"ts": float(i), "power_w": 200.0, "sm_pct": 40.0,
                     "device": 1} for i in range(n)],
    }))
    (dumps / "c_garbage.json").write_text("{not json")
    store = TelemetryStore(tmp_path / "store")
    obs.enable()
    try:
        obs.reset()
        prod = DcgmDirectoryProducer(store, dumps)
        assert prod.step() == 3
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert len(prod.verdicts) == 2            # garbage skipped, not ingested
    assert len(store.manifest["shards"]) == 2
    assert store.total_rows == 2 * n
    assert ('repro_shards_quarantined_total{reason="unparseable_dump"} 1'
            in text)
    assert prod.step() == 0                   # repoll is idempotent


def test_parse_power_json_shapes():
    cols, kw = parse_power_json({"DCGM_FI_DEV_POWER_USAGE": [1.0],
                                 "timestamp": [0.0], "hostname": 4})
    assert "DCGM_FI_DEV_POWER_USAGE" in cols and kw["hostname"] == 4
    cols, kw = parse_power_json([{"ts": 1.0, "power_w": 99.0,
                                  "sm_pct": 50.0}])
    assert cols["DCGM_FI_DEV_POWER_USAGE"] == [99.0]
    assert cols["DCGM_FI_PROF_SM_ACTIVE"] == [0.5]   # percent -> ratio
    with pytest.raises(ValueError):
        parse_power_json({"neither": "layout"})
    with pytest.raises(ValueError):
        parse_power_json("a string")


# --------------------------------------------------------------------------- #
# observability families (satellite 5)
# --------------------------------------------------------------------------- #
def test_live_families_registered_and_lintable(tmp_path):
    obs.enable()
    try:
        obs.reset()
        obs.init_live_metrics()
        text = obs.render_prometheus()
    finally:
        obs.disable()
        obs.reset()
    assert obs.lint_exposition(text) == []
    for name, kind, _ in obs.LIVE_FAMILIES:
        sample = f"{name}_count" if kind == "histogram" else name
        assert f"\n{sample}" in text or text.startswith(sample)
    prom = tmp_path / "metrics.prom"
    prom.write_text(text)
    import prom_lint
    assert prom_lint.check_file(str(prom), [
        "repro_live_ticks_total", "repro_live_staleness_seconds_count",
        "repro_live_checkpoint_writes_total",
        "repro_live_checkpoint_restores_total",
        "repro_live_coalesced_shards_total"]) == []

"""Algorithm 1 controller + power model + imbalance scheduler tests."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.controller import (ControllerConfig, DownscaleMode,
                                   ExecutionIdleController)
from repro.core.imbalance import ImbalanceScheduler, PoolConfig, PoolPolicy
from repro.core.power_model import (ClockLevel, PLATFORMS, SimulatedDevice,
                                    get_platform)

IDLE = {"sm": 0.0, "dram": 0.0, "pcie_rx": 0.0}
BUSY = {"sm": 0.9, "dram": 0.5, "pcie_rx": 0.0}


def make(mode=DownscaleMode.SM_ONLY, x=3.0, y=5.0):
    dev = SimulatedDevice(get_platform("l40s"))
    ctl = ExecutionIdleController(dev, ControllerConfig(
        threshold_x_s=x, cooldown_y_s=y, mode=mode))
    return dev, ctl


def test_downscale_after_threshold():
    dev, ctl = make()
    for t in range(3):
        ctl.step(float(t), IDLE)
        assert not ctl.downscaled          # c <= X so far
    ctl.step(3.0, IDLE)
    assert ctl.downscaled                  # c = 4 > X
    assert dev.clocks() == (ClockLevel.MIN, ClockLevel.MAX)


def test_restore_on_activity_and_cooldown():
    dev, ctl = make()
    for t in range(5):
        ctl.step(float(t), IDLE)
    assert ctl.downscaled
    ctl.step(5.0, BUSY)
    assert not ctl.downscaled
    assert dev.clocks() == (ClockLevel.MAX, ClockLevel.MAX)
    # cooldown: immediate re-idle must NOT downscale before t=10 (y=5)
    for t in range(6, 10):
        ctl.step(float(t), IDLE)
        assert not ctl.downscaled
    ctl.step(10.0, IDLE)
    assert ctl.downscaled


def test_sm_and_mem_mode_reaches_deep_idle_power():
    dev, ctl = make(mode=DownscaleMode.SM_AND_MEM)
    for t in range(5):
        ctl.step(float(t), IDLE)
    plat = get_platform("l40s")
    # §5.3: SM+mem downscale lands at deep-idle power (35 W on L40S)
    assert dev.power_w(10.0, 0.0) == pytest.approx(plat.deep_idle_w)


def test_busy_never_downscales():
    dev, ctl = make()
    for t in range(50):
        ctl.step(float(t), BUSY)
    assert not ctl.downscaled
    assert ctl.stats.downscale_events == 0


@given(st.integers(0, 2**31 - 1), st.floats(1.0, 6.0), st.floats(1.0, 8.0))
@settings(max_examples=30, deadline=None)
def test_controller_invariants(seed, x, y):
    """Clocks are MIN only while `downscaled`; restore always follows
    activity; downscale only after > x consecutive idle seconds."""
    rng = np.random.default_rng(seed)
    dev, ctl = make(x=x, y=y)
    idle_run = 0.0
    for t in range(200):
        idle = rng.random() < 0.6
        ctl.step(float(t), IDLE if idle else BUSY)
        idle_run = idle_run + 1.0 if idle else 0.0
        if ctl.downscaled:
            assert dev.clocks()[0] == ClockLevel.MIN
            assert idle_run > x                   # only after sustained idle
        if not idle:
            assert not ctl.downscaled             # activity restores


def test_cooldown_boundary_t_equals_t_cooldown_downscales():
    """Algorithm 1 uses `t >= t_cooldown`: the boundary step itself may
    downscale — one step earlier must not."""
    dev, ctl = make(x=1.0, y=5.0)
    for t in range(3):
        ctl.step(float(t), IDLE)
    assert ctl.downscaled
    ctl.step(3.0, BUSY)                    # restore -> t_cooldown = 8.0
    assert not ctl.downscaled
    # idle from t=4: c exceeds X at t=5 but the cooldown gates until t=8
    for t in range(4, 8):
        ctl.step(float(t), IDLE)
        assert not ctl.downscaled, f"t={t} is inside the cooldown window"
    ctl.step(8.0, IDLE)                    # t == t_cooldown exactly
    assert ctl.downscaled
    assert ctl.stats.downscale_events == 2


def test_sm_and_mem_mode_sets_and_restores_both_clocks():
    dev, ctl = make(mode=DownscaleMode.SM_AND_MEM)
    for t in range(5):
        ctl.step(float(t), IDLE)
    assert dev.clocks() == (ClockLevel.MIN, ClockLevel.MIN)
    ctl.step(5.0, BUSY)
    assert dev.clocks() == (ClockLevel.MAX, ClockLevel.MAX)
    # sm-only mode must leave the memory clock alone
    dev2, ctl2 = make(mode=DownscaleMode.SM_ONLY)
    for t in range(5):
        ctl2.step(float(t), IDLE)
    assert dev2.clocks() == (ClockLevel.MIN, ClockLevel.MAX)


def test_retrigger_immediately_after_upscale():
    """A single busy second after restore: c resets, and once the cooldown
    passes the controller must re-downscale after X fresh idle seconds."""
    dev, ctl = make(x=2.0, y=1.0)
    for t in range(4):
        ctl.step(float(t), IDLE)
    assert ctl.downscaled
    ctl.step(4.0, BUSY)                    # restore; t_cooldown = 5.0
    assert not ctl.downscaled
    assert ctl.stats.restore_events == 1
    # idle again immediately: c=1,2 at t=5,6; c>X at t=7 >= cooldown
    for t, expect in ((5.0, False), (6.0, False), (7.0, True)):
        ctl.step(t, IDLE)
        assert ctl.downscaled is expect, f"t={t}"
    assert ctl.stats.downscale_events == 2
    assert dev.switch_count == 3           # down, up, down


# --------------------------------------------------------------------------- #
# power model
# --------------------------------------------------------------------------- #
def test_exec_idle_above_deep_idle_all_platforms():
    """Fig 4: execution-idle power >> deep-idle on every platform."""
    for name, plat in PLATFORMS.items():
        assert plat.exec_idle_w > plat.deep_idle_w, name
        assert plat.power_w(0.0, resident=True) > plat.power_w(0.0, resident=False)
        assert plat.power_w(1.0) <= plat.tdp_w * 1.0001


def test_power_monotone_in_util():
    plat = get_platform("tpu_v5e")
    p = [plat.power_w(u) for u in np.linspace(0, 1, 11)]
    assert all(b >= a for a, b in zip(p, p[1:]))


def test_switch_latency_stalls():
    dev = SimulatedDevice(get_platform("l40s"), switch_latency_s=0.3)
    dev.set_clocks(10.0, ClockLevel.MIN, ClockLevel.MAX)
    assert dev.perf_scale(10.1) == 0.0     # mid-switch
    assert dev.perf_scale(10.4) > 0.0


# --------------------------------------------------------------------------- #
# imbalance scheduler (§5.1)
# --------------------------------------------------------------------------- #
def test_consolidated_routes_only_to_active():
    pool = PoolConfig(n_devices=8, policy=PoolPolicy.CONSOLIDATED, n_active=2)
    sched = ImbalanceScheduler(pool)
    for _ in range(100):
        assert sched.route(1.0) in (0, 1)
    assert sched.inactive_devices() == tuple(range(2, 8))


def test_balanced_join_shortest_queue():
    sched = ImbalanceScheduler(PoolConfig(n_devices=4))
    targets = [sched.route(1.0) for _ in range(8)]
    # equal work -> round-robin-like spread: every device got 2
    assert sorted(targets) == [0, 0, 1, 1, 2, 2, 3, 3]


@given(st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_work_conservation(n_active, seed):
    rng = np.random.default_rng(seed)
    sched = ImbalanceScheduler(PoolConfig(
        n_devices=8, policy=PoolPolicy.CONSOLIDATED, n_active=n_active))
    work = rng.uniform(0.5, 5.0, 50)
    for w in work:
        sched.route(float(w))
    assert sum(sched.outstanding) == pytest.approx(float(work.sum()))
    assert sum(sched.routed) == 50

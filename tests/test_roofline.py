"""HLO-analyzer tests: loop awareness, collective accounting, dot flops."""
import textwrap

from repro.roofline.hlo_parse import analyze_hlo

SIMPLE = textwrap.dedent("""\
    HloModule test

    %region_body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %ar = f32[8,8]{1,0} all-reduce(%x), replica_groups=[4,4]<=[16], to_apply=%add
      ROOT %t = (s32[], f32[8,8]) tuple(%i, %ar)
    }

    %region_cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %c = s32[] constant(10)
      ROOT %lt = pred[] compare(%i2, %c), direction=LT
    }

    ENTRY %main (a: f32[16,32], b: f32[32,64]) -> f32[16,64] {
      %a = f32[16,32]{1,0} parameter(0)
      %b = f32[32,64]{1,0} parameter(1)
      %d = f32[16,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %init = (s32[], f32[8,8]) tuple(%zero, %buf)
      %w = (s32[], f32[8,8]) while(%init), condition=%region_cond, body=%region_body
      ROOT %out = f32[16,64]{1,0} copy(%d)
    }
    """)


def test_dot_flops():
    stats = analyze_hlo(SIMPLE)
    # 2 * 16 * 64 * 32 = 65536
    assert stats.flops == 2 * 16 * 64 * 32


def test_loop_multiplied_collectives():
    stats = analyze_hlo(SIMPLE)
    # all-reduce of f32[8,8] = 256 B, 10 loop trips
    assert stats.collective_counts["all-reduce"] == 10
    assert stats.collective_by_op["all-reduce"] == 256 * 10


def test_materializing_bytes_counted():
    stats = analyze_hlo(SIMPLE)
    # dot: out 16*64*4 + operands (16*32 + 32*64)*4 ; copy: out+operand
    dot_bytes = (16 * 64 + 16 * 32 + 32 * 64) * 4
    copy_bytes = 2 * 16 * 64 * 4
    ar_bytes = 256 * 2 * 10         # operand+output per trip
    assert stats.hbm_bytes == dot_bytes + copy_bytes + ar_bytes


def test_allgather_group_scaling():
    hlo = textwrap.dedent("""\
        HloModule t

        ENTRY %main (x: f32[4,8]) -> f32[16,8] {
          %x = f32[4,8]{1,0} parameter(0)
          ROOT %ag = f32[16,8]{1,0} all-gather(%x), replica_groups=[4,4]<=[16], dimensions={0}
        }
        """)
    stats = analyze_hlo(hlo)
    # operand = output / group_size = 16*8*4 / 4
    assert stats.collective_by_op["all-gather"] == 16 * 8 * 4 / 4


def test_derive_terms_bottleneck():
    from repro.roofline.analysis import derive_terms
    from repro.roofline.hlo_parse import HloStats, COLLECTIVE_OPS
    stats = HloStats(
        flops=197e12, hbm_bytes=819e9 * 2, collective_bytes=50e9 * 0.5,
        collective_by_op={o: 0.0 for o in COLLECTIVE_OPS},
        collective_counts={o: 0.0 for o in COLLECTIVE_OPS})
    terms = derive_terms({}, stats, n_chips=256,
                         model_flops_global=197e12 * 256 * 0.5)
    assert terms.compute_s == 1.0
    assert terms.memory_s == 2.0
    assert terms.collective_s == 0.5
    assert terms.bottleneck == "memory"
    assert terms.useful_fraction == 0.5

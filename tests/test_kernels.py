"""Per-kernel shape/dtype sweeps: Pallas vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import run_replay as rr
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.rwkv6_scan import wkv6
from repro.kernels.ssm_scan import ssm_scan

KEY = jax.random.PRNGKey(0)

#: the same detection the public ops wrappers use: interpret everywhere
#: but TPU (``REPRO_PALLAS_INTERPRET`` overrides), so CPU-only CI runs
#: the whole suite green in interpret mode while TPU CI exercises the
#: compiled kernels with no test edits.
INTERPRET = rr.default_interpret()


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,kv,s,d", [
    (2, 4, 2, 256, 64), (1, 8, 1, 128, 128), (2, 2, 2, 512, 64),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, d, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=INTERPRET)
    expect = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_flash_attention_uneven_heads():
    """GQA with q_per_kv=3 (hymba-like 25H/5KV pattern scaled down)."""
    q = jax.random.normal(KEY, (1, 6, 128, 64))
    k = jax.random.normal(KEY, (1, 2, 128, 64))
    v = jax.random.normal(KEY, (1, 2, 128, 64))
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=INTERPRET)
    expect = ref.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,kv,s,d,cl", [
    (2, 8, 2, 1024, 64, 700), (1, 4, 4, 512, 128, 512),
    (2, 2, 1, 512, 64, 1), (1, 16, 2, 2048, 64, 1500),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kv, s, d, cl, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = decode_attention(q, kc, vc, cl, block_k=256, interpret=INTERPRET)
    expect = ref.decode_attention_reference(q, kc, vc, cl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


# --------------------------------------------------------------------------- #
# rwkv6 wkv
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("b,h,s,kd,chunk", [
    (2, 3, 64, 16, 16), (1, 2, 128, 32, 32), (1, 1, 96, 64, 32),
    (2, 2, 64, 32, 64),  # chunk > s falls back to one chunk
])
def test_wkv6(b, h, s, kd, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, h, s, kd))
    k = jax.random.normal(ks[1], (b, h, s, kd))
    v = jax.random.normal(ks[2], (b, h, s, kd))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, h, s, kd))) * 0.55 + 0.4
    u = jax.random.normal(ks[4], (h, kd)) * 0.1
    y, state = wkv6(r, k, v, w, u, chunk=chunk, interpret=INTERPRET)
    ye, se = ref.wkv6_reference(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(state), np.asarray(se), rtol=1e-3, atol=1e-3)


def test_wkv6_extreme_decay():
    """Decays near 0 and near 1 stay finite (log-space in-chunk form)."""
    b, h, s, kd = 1, 1, 64, 16
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (b, h, s, kd))
    k = jax.random.normal(ks[1], (b, h, s, kd))
    v = jax.random.normal(ks[2], (b, h, s, kd))
    w = jnp.where(jax.random.bernoulli(ks[3], 0.5, (b, h, s, kd)), 0.999, 1e-4)
    y, state = wkv6(r, k, v, w, u=jnp.zeros((h, kd)), chunk=32, interpret=INTERPRET)
    assert np.isfinite(np.asarray(y)).all()
    ye, _ = ref.wkv6_reference(r, k, v, w, jnp.zeros((h, kd)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------- #
# mamba selective scan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("bsz,s,di,n,chunk,bi", [
    (2, 64, 32, 8, 16, 32), (1, 96, 64, 16, 32, 32), (2, 128, 128, 16, 32, 64),
])
def test_ssm_scan(bsz, s, di, n, chunk, bi):
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (bsz, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, di)))
    a = -jnp.exp(jax.random.normal(ks[2], (di, n)) * 0.5)
    b = jax.random.normal(ks[3], (bsz, s, n))
    c = jax.random.normal(ks[4], (bsz, s, n))
    y, h = ssm_scan(u, dt, a, b, c, chunk=chunk, block_i=bi, interpret=INTERPRET)
    ye, he = ref.ssm_scan_reference(u, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------- #
# rmsnorm
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(4, 128), (3, 50, 128), (1, 7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
    out = rmsnorm(x, w, interpret=INTERPRET)
    expect = ref.rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


# --------------------------------------------------------------------------- #
# run-replay cap-bucket scan
# --------------------------------------------------------------------------- #
def _np_cap_counts(sorted_p, caps):
    sp = np.asarray(sorted_p)
    cv = np.asarray(caps)
    return np.stack([
        sp.shape[1] - np.searchsorted(sp[r], cv[r], side="right")
        for r in range(sp.shape[0])]).astype(np.int32)


@pytest.mark.parametrize("rows,n,c", [(3, 17, 5), (1, 1, 7), (4, 256, 33),
                                      (2, 64, 1)])
def test_cap_bucket_scan(rows, n, c):
    ks = jax.random.split(KEY, 2)
    sp = jnp.sort(jax.random.normal(ks[0], (rows, n)) * 100.0, axis=1)
    caps = jax.random.normal(ks[1], (rows, c)) * 100.0
    expect = _np_cap_counts(sp, caps)
    out = rr.cap_bucket_scan(sp, caps, interpret=INTERPRET)
    np.testing.assert_array_equal(np.asarray(out), expect)
    np.testing.assert_array_equal(
        np.asarray(rr.cap_bucket_scan_reference(sp, caps)), expect)


def test_cap_bucket_scan_ties_and_padding():
    """Exact ties follow ``side="right"`` (p > cap strictly), and -inf
    front-padding — how the replay backend widens ragged power buckets —
    never changes the counts."""
    sp = jnp.asarray([[1.0, 2.0, 2.0, 2.0, 3.0, 3.0]])
    caps = jnp.asarray([[0.5, 2.0, 3.0, 4.0, 1.0]])
    expect = np.array([[6, 2, 0, 0, 5]], np.int32)
    for fn in (lambda a, b: rr.cap_bucket_scan(a, b, interpret=INTERPRET),
               rr.cap_bucket_scan_reference):
        np.testing.assert_array_equal(np.asarray(fn(sp, caps)), expect)
        padded = jnp.concatenate(
            [jnp.full((1, 5), -jnp.inf, sp.dtype), sp], axis=1)
        np.testing.assert_array_equal(np.asarray(fn(padded, caps)), expect)


def test_cap_bucket_counts_dispatcher_and_ops_wrapper():
    ks = jax.random.split(KEY, 2)
    sp = jnp.sort(jax.random.normal(ks[0], (5, 40)), axis=1)
    caps = jax.random.normal(ks[1], (5, 9))
    expect = _np_cap_counts(sp, caps)
    np.testing.assert_array_equal(
        np.asarray(rr.cap_bucket_counts(sp, caps)), expect)
    np.testing.assert_array_equal(
        np.asarray(rr.cap_bucket_counts(sp, caps, use_pallas=False)), expect)
    np.testing.assert_array_equal(
        np.asarray(ops.cap_bucket_scan(sp, caps)), expect)


def test_default_interpret_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert rr.default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert rr.default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "false")
    assert rr.default_interpret() is False
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET")
    assert rr.default_interpret() is (jax.default_backend() != "tpu")

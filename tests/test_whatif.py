"""What-if policy engine tests.

The load-bearing guarantee: the vectorized downscale policy reproduces the
step-by-step :class:`ExecutionIdleController` decision sequence *exactly* on
recorded signal streams — simulator and DES telemetry, any chunking — and
the replayer/sweep are bit-identical under chunking and process-pool width.
"""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.cluster import generate_cluster
from repro.core.controller import (ControllerConfig, DownscaleMode,
                                   ExecutionIdleController)
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.power_model import SimulatedDevice, get_platform
from repro.core.states import DeviceState
from repro.serving.des import simulate_pool
from repro.serving.latency import Request
from repro.serving.perf_model import LLAMA13B_L40S
from repro.telemetry import TelemetryStore
from repro.whatif import (DownscalePolicy, NoOpPolicy, ParkingPolicy,
                          PolicyReplayer, PowerCapPolicy, downscale_decisions,
                          default_policy_grid, format_frontier,
                          frontier_from_dict, frontier_to_dict,
                          low_activity_series, replay_store, run_sweep,
                          sweep_frame)

_COMP = ("sm", "tensor", "fp16", "fp32", "fp64", "dram")
_COMM = ("pcie_tx", "pcie_rx", "nvlink_tx", "nvlink_rx", "ici_tx", "ici_rx")


def step_controller_decisions(seg, cfg: ControllerConfig) -> np.ndarray:
    """Reference: the stateful controller stepped sample by sample, fed the
    same cleaned signals the vectorized policy reads (activity as fractions,
    NaN -> 0.0 for unavailable)."""
    ctl = ExecutionIdleController(SimulatedDevice(get_platform("l40s")), cfg)
    cols = {k: np.nan_to_num(seg[k], nan=0.0) for k in _COMP + _COMM}
    ts = seg["timestamp"]
    out = np.empty(len(seg), dtype=bool)
    for i in range(len(seg)):
        sample = {k: cols[k][i] / 100.0 for k in _COMP}
        sample.update({k: cols[k][i] for k in _COMM})
        out[i] = ctl.step(float(ts[i]), sample)
    return out


def vectorized_decisions(seg, cfg: ControllerConfig, chunk: int) -> np.ndarray:
    low = low_activity_series(seg, cfg)
    ts = seg["timestamp"]
    carry = DownscalePolicy(config=cfg).init_carry()
    outs = []
    for s in range(0, len(seg), chunk):
        o, carry, _, _ = downscale_decisions(ts[s:s + chunk], low[s:s + chunk],
                                             cfg, carry)
        outs.append(o)
    return np.concatenate(outs)


def job_streams(frame, limit=None):
    segs = [(k, seg) for k, seg in frame.group_streams() if k[0] >= 0]
    return segs[:limit] if limit else segs


# --------------------------------------------------------------------------- #
# decision-sequence equivalence (acceptance criterion)
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1), st.floats(1.0, 6.0), st.floats(1.0, 8.0))
@settings(max_examples=5, deadline=None)
def test_downscale_matches_controller_on_simulator_streams(seed, x, y):
    cs = generate_cluster(n_devices=2, horizon_s=1500, seed=seed % 1000)
    cfg = ControllerConfig(threshold_x_s=x, cooldown_y_s=y)
    checked = 0
    for _, seg in job_streams(cs.frame, limit=3):
        ref = step_controller_decisions(seg, cfg)
        for chunk in (len(seg), 97):
            assert np.array_equal(vectorized_decisions(seg, cfg, chunk), ref)
        checked += 1
    assert checked > 0


def test_downscale_matches_controller_modes_and_one_row_chunks():
    cs = generate_cluster(n_devices=2, horizon_s=1200, seed=11)
    for cfg in (ControllerConfig(),
                ControllerConfig(threshold_x_s=1.0, cooldown_y_s=2.0,
                                 mode=DownscaleMode.SM_AND_MEM),
                ControllerConfig(threshold_x_s=5.5, cooldown_y_s=7.0)):
        for _, seg in job_streams(cs.frame, limit=2):
            ref = step_controller_decisions(seg, cfg)
            for chunk in (len(seg), 1, 13):
                assert np.array_equal(vectorized_decisions(seg, cfg, chunk),
                                      ref)


def test_downscale_matches_controller_on_des_telemetry():
    rng = np.random.default_rng(3)
    trace = [Request(req_id=i, arrival_s=float(rng.uniform(0, 100)),
                     prompt_tokens=200, output_tokens=30)
             for i in range(25)]
    res = simulate_pool(trace, get_platform("l40s"), LLAMA13B_L40S,
                        PoolConfig(n_devices=2), duration_s=140.0)
    cfg = ControllerConfig()
    segs = job_streams(res.telemetry)
    assert segs, "DES must emit job-attributed telemetry"
    for _, seg in segs:
        ref = step_controller_decisions(seg, cfg)
        for chunk in (len(seg), 7):
            assert np.array_equal(vectorized_decisions(seg, cfg, chunk), ref)


# --------------------------------------------------------------------------- #
# replayer semantics
# --------------------------------------------------------------------------- #
def test_noop_policy_is_the_identity():
    cs = generate_cluster(n_devices=2, horizon_s=1800, seed=4)
    rep = PolicyReplayer(NoOpPolicy(), min_job_duration_s=300)
    rep.update(cs.frame)
    res = rep.finalize()
    assert res.energy_saved_j == 0.0
    assert res.penalty_s == 0.0
    assert res.baseline.energy_j == res.counterfactual.energy_j
    assert res.baseline.time_s == res.counterfactual.time_s


def test_downscale_replay_saves_energy_not_time():
    cs = generate_cluster(n_devices=3, horizon_s=2700, seed=9)
    pol = DownscalePolicy(config=ControllerConfig(threshold_x_s=1.0,
                                                  cooldown_y_s=2.0,
                                                  mode=DownscaleMode.SM_AND_MEM))
    rep = PolicyReplayer(pol, min_job_duration_s=300)
    rep.update(cs.frame)
    res = rep.finalize()
    assert res.energy_saved_j > 0.0
    assert res.downscale_events > 0
    assert res.penalty_s > 0.0
    # downscaling re-prices power; it never reclassifies time
    assert res.baseline.time_s == res.counterfactual.time_s


def test_replayer_chunking_bit_identical():
    cs = generate_cluster(n_devices=3, horizon_s=2700, seed=21)
    pol = DownscalePolicy()
    mono = PolicyReplayer(pol, min_job_duration_s=600)
    mono.update(cs.frame)
    a = mono.finalize()
    for chunk_rows in (997, 1800):
        rep = PolicyReplayer(pol, min_job_duration_s=600)
        for chunk in cs.frame.iter_chunks(chunk_rows):
            rep.update(chunk)
        b = rep.finalize()
        assert [j.job_id for j in a.jobs] == [j.job_id for j in b.jobs]
        for ja, jb in zip(a.jobs, b.jobs):
            assert ja.baseline.energy_j == jb.baseline.energy_j
            assert ja.counterfactual.energy_j == jb.counterfactual.energy_j
            assert ja.counterfactual.time_s == jb.counterfactual.time_s
            assert ja.penalty_s == jb.penalty_s
            assert ja.wake_events == jb.wake_events
        assert a.counterfactual.energy_j == b.counterfactual.energy_j
        assert a.penalty_s == b.penalty_s


def test_parking_policy_parks_idle_and_prices_wakes():
    # one parked device (k=1 of 2 -> device_id 1 parks), alternating blocks
    rows = []
    for t in range(60):
        active = (t // 10) % 2 == 0
        rows.append({
            "timestamp": float(t), "job_id": 3, "device_id": 1, "hostname": 0,
            "program_resident": 1, "sm": 80.0 if active else 1.0,
            "power": 250.0 if active else 105.0, "platform": 0,
        })
    from repro.telemetry.records import TelemetryFrame
    frame = TelemetryFrame.from_rows(rows)
    pool = PoolConfig(n_devices=2, policy=PoolPolicy.CONSOLIDATED, n_active=1)
    pol = ParkingPolicy(pool=pool, resume_latency_s=7.0)
    rep = PolicyReplayer(pol, min_job_duration_s=0.0)
    rep.update(frame)
    res = rep.finalize()
    # 3 idle decades -> 30 parked seconds at deep-idle (35 W on l40s),
    # 2 idle->active wake-ups (t=20 and t=40 boundaries)
    assert res.counterfactual.time_s[DeviceState.DEEP_IDLE] == 30.0
    assert res.counterfactual.energy_j[DeviceState.DEEP_IDLE] == 30 * 35.0
    assert res.wake_events == 2
    assert res.penalty_s == 2 * 7.0
    assert res.energy_saved_j == pytest.approx(30 * (105.0 - 35.0))
    # an active device under the same pool is untouched
    rows2 = [dict(r, device_id=0) for r in rows]
    rep2 = PolicyReplayer(pol, min_job_duration_s=0.0)
    rep2.update(TelemetryFrame.from_rows(rows2))
    res2 = rep2.finalize()
    assert res2.energy_saved_j == 0.0 and res2.penalty_s == 0.0


def test_power_cap_policy_caps_and_slows():
    from repro.telemetry.records import TelemetryFrame
    rows = [{"timestamp": float(t), "job_id": 1, "device_id": 0, "hostname": 0,
             "program_resident": 1, "sm": 90.0, "power": 380.0, "platform": 0}
            for t in range(20)]
    frame = TelemetryFrame.from_rows(rows)
    pol = PowerCapPolicy(cap_fraction=0.5)          # 200 W on the 400 W l40s
    rep = PolicyReplayer(pol, min_job_duration_s=0.0)
    rep.update(frame)
    res = rep.finalize()
    assert res.counterfactual.energy_j[DeviceState.ACTIVE] == 20 * 200.0
    expected_penalty = 20 * ((380.0 / 200.0) ** (1 / 3) - 1.0)
    assert res.penalty_s == pytest.approx(expected_penalty)


# --------------------------------------------------------------------------- #
# sweep: workers parity, frontier structure, serialization
# --------------------------------------------------------------------------- #
def small_grid():
    return [
        NoOpPolicy(),
        DownscalePolicy(),
        DownscalePolicy(config=ControllerConfig(
            threshold_x_s=1.0, cooldown_y_s=2.0, mode=DownscaleMode.SM_AND_MEM)),
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=2)),
        PowerCapPolicy(cap_fraction=0.5),
    ]


def test_sweep_workers_bit_identical_and_pareto_sound():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=6, horizon_s=2400, seed=17,
                         store=store, shard_s=600)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        f1 = run_sweep(store, small_grid(), workers=1, min_job_duration_s=600)
        f2 = run_sweep(store, small_grid(), workers=2, min_job_duration_s=600)
        assert frontier_to_dict(f1) == frontier_to_dict(f2)
    assert len(f1.outcomes) == 5
    assert f1.n_jobs > 0 and f1.n_rows > 0
    pareto = f1.pareto_set()
    assert pareto
    for o in pareto:       # no pareto member may be dominated
        assert not any(
            p.energy_saved_j >= o.energy_saved_j and p.penalty_s <= o.penalty_s
            and (p.energy_saved_j > o.energy_saved_j or p.penalty_s < o.penalty_s)
            for p in f1.outcomes)
    noop = next(o for o in f1.outcomes if o.name == "noop")
    assert noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0


def test_sweep_frame_and_report_roundtrip():
    cs = generate_cluster(n_devices=2, horizon_s=1500, seed=23)
    frontier = sweep_frame(cs.frame, small_grid(), min_job_duration_s=300)
    payload = frontier_to_dict(frontier)
    assert frontier_from_dict(payload) == frontier
    text = format_frontier(frontier)
    assert "what-if frontier" in text and "noop" in text
    # per-job CDFs are sorted and sized to the job count
    for o in frontier.outcomes:
        cdf = o.per_job_saved_fraction
        assert len(cdf) == o.n_jobs
        assert list(cdf) == sorted(cdf)


def test_replay_store_matches_in_memory_replayer():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=3, horizon_s=1800, seed=29,
                         store=store, shard_s=450)
        streamed = replay_store(store, DownscalePolicy(),
                                min_job_duration_s=600)
        mono_cs = generate_cluster(n_devices=3, horizon_s=1800, seed=29)
        rep = PolicyReplayer(DownscalePolicy(), min_job_duration_s=600)
        rep.update(mono_cs.frame)
        mono = rep.finalize()
    assert [j.job_id for j in streamed.jobs] == [j.job_id for j in mono.jobs]
    assert streamed.counterfactual.energy_j == mono.counterfactual.energy_j
    assert streamed.penalty_s == mono.penalty_s


def test_default_policy_grid_is_dense_and_unique():
    # dense default (200) for the batched path; the legacy 48-config grid
    # stays available as the committed benchmark baseline — sizes and
    # uniqueness are asserted in tests/test_whatif_batched.py
    grid = default_policy_grid()
    assert len(grid) == 200
    assert len(default_policy_grid(dense=False)) == 48


def test_replayer_merge_rejects_overlap_and_config_mismatch():
    cs = generate_cluster(n_devices=2, horizon_s=900, seed=31)
    a = PolicyReplayer(NoOpPolicy(), min_job_duration_s=0.0)
    b = PolicyReplayer(NoOpPolicy(), min_job_duration_s=0.0)
    a.update(cs.frame)
    b.update(cs.frame)
    with pytest.raises(ValueError, match="overlapping"):
        a.merge(b)
    with pytest.raises(ValueError, match="configs"):
        a.merge(PolicyReplayer(NoOpPolicy(), min_job_duration_s=123.0))
    with pytest.raises(ValueError, match="configs"):
        a.merge(PolicyReplayer(PowerCapPolicy(), min_job_duration_s=0.0))


def test_policy_config_validation():
    """Malformed grid points fail at construction with a named knob, not
    deep inside the replay."""
    with pytest.raises(ValueError, match="threshold_x_s"):
        DownscalePolicy(config=ControllerConfig(threshold_x_s=0.0))
    with pytest.raises(ValueError, match="threshold_x_s"):
        DownscalePolicy(config=ControllerConfig(threshold_x_s=-3.0))
    with pytest.raises(ValueError, match="cooldown_y_s"):
        DownscalePolicy(config=ControllerConfig(cooldown_y_s=-1.0))
    with pytest.raises(ValueError, match="interval_eps_s"):
        DownscalePolicy(config=ControllerConfig(interval_eps_s=0.0))
    with pytest.raises(ValueError, match="switch_latency_s"):
        DownscalePolicy(switch_latency_s=-0.1)
    with pytest.raises(ValueError, match="n_active"):
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=0))
    with pytest.raises(ValueError, match="n_active"):
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=5))
    with pytest.raises(ValueError, match="1 device"):
        ParkingPolicy(pool=PoolConfig(n_devices=0))
    with pytest.raises(ValueError, match="resume_latency_s"):
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=2), resume_latency_s=-1.0)
    for bad_cap in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError, match="cap_fraction"):
            PowerCapPolicy(cap_fraction=bad_cap)
    # valid boundary values construct fine
    PowerCapPolicy(cap_fraction=1.0)
    ParkingPolicy(pool=PoolConfig(n_devices=4,
                                  policy=PoolPolicy.CONSOLIDATED, n_active=4))
    DownscalePolicy(config=ControllerConfig(threshold_x_s=0.01))


def test_power_cap_penalty_prices_at_replayer_dt():
    from repro.telemetry.records import TelemetryFrame
    rows = [{"timestamp": float(2 * t), "job_id": 1, "device_id": 0,
             "hostname": 0, "program_resident": 1, "sm": 90.0, "power": 380.0,
             "platform": 0}
            for t in range(20)]
    frame = TelemetryFrame.from_rows(rows)
    rep = PolicyReplayer(PowerCapPolicy(cap_fraction=0.5),
                         min_job_duration_s=0.0, dt_s=2.0)
    rep.update(frame)
    res = rep.finalize()
    # 2 s samples: both the capped energy and the stall time double
    assert res.counterfactual.energy_j[DeviceState.ACTIVE] == 20 * 200.0 * 2.0
    assert res.penalty_s == pytest.approx(
        2.0 * 20 * ((380.0 / 200.0) ** (1 / 3) - 1.0))

"""Closed-loop Pareto search: budget accounting, knee soundness, refinement,
determinism, and the evaluate() kernel contract.
"""
import tempfile

import numpy as np
import pytest

from repro.cluster import generate_cluster
from repro.telemetry import TelemetryStore
from repro.whatif import (CategoricalAxis, ContinuousAxis, PenaltyBudget,
                          PolicyFamily, PowerCapPolicy, achievable_saving,
                          default_families, evaluate, find_knee,
                          frontier_to_dict, run_sweep, search_frontier)
from repro.whatif.sweep import assemble_frontier


@pytest.fixture(scope="module")
def store_dir():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=8, horizon_s=2700, seed=3,
                         store=store, shard_s=900)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        yield d


def _store(store_dir):
    return TelemetryStore(store_dir)


# --------------------------------------------------------------------------- #
# evaluate(): the kernel contract
# --------------------------------------------------------------------------- #
def test_evaluate_matches_run_sweep_outcomes(store_dir):
    store = _store(store_dir)
    from repro.whatif import default_policy_grid
    grid = default_policy_grid(dense=False)[:10]
    outcomes = evaluate(grid, store, min_job_duration_s=0.0)
    assert len(outcomes) == len(grid)
    assert all(not o.pareto for o in outcomes)   # flags belong to sets
    swept = run_sweep(store, grid, min_job_duration_s=0.0)
    flagged = assemble_frontier(outcomes, swept.n_rows, swept.n_runs)
    assert frontier_to_dict(flagged) == frontier_to_dict(swept)


# --------------------------------------------------------------------------- #
# search: budget, knee, convergence
# --------------------------------------------------------------------------- #
def test_search_respects_eval_budget_and_flags_pareto(store_dir):
    store = _store(store_dir)
    res = search_frontier(store, max_evals=50, min_job_duration_s=0.0)
    assert res.n_evals <= 50
    assert res.n_evals == len(res.frontier.outcomes)
    assert res.n_rounds == len(res.history)
    assert res.history[-1].n_evals_total == res.n_evals
    # pareto soundness over everything evaluated
    for o in res.frontier.pareto_set():
        assert not any(
            p.energy_saved_j >= o.energy_saved_j
            and p.penalty_s <= o.penalty_s
            and (p.energy_saved_j > o.energy_saved_j
                 or p.penalty_s < o.penalty_s)
            for p in res.frontier.outcomes)
    # the noop anchor is present and untouched
    noop = next(o for o in res.frontier.outcomes if o.name == "noop")
    assert noop.energy_saved_j == 0.0 and noop.penalty_s == 0.0
    # knee is on the front, and without a budget best == knee
    assert res.knee.pareto
    assert res.best == res.knee


def test_search_refines_around_the_knee(store_dir):
    store = _store(store_dir)
    res = search_frontier(store, min_job_duration_s=0.0)
    assert res.n_rounds >= 2                      # refinement happened
    assert sum(r.n_new for r in res.history) == res.n_evals
    coarse = res.history[0].n_evals_total
    assert res.n_evals > coarse                   # beyond the coarse grids
    # refinement improves (or maintains) the knee's saved energy
    assert (res.history[-1].knee_saved_fraction
            >= res.history[0].knee_saved_fraction)


def test_search_budget_feasibility(store_dir):
    store = _store(store_dir)
    budget = PenaltyBudget(max_penalty_fraction=0.005)
    res = search_frontier(store, budget=budget, min_job_duration_s=0.0)
    assert res.best is not None
    assert res.best.penalty_fraction <= 0.005
    # best is the max-saving feasible config over everything evaluated
    for o in res.frontier.outcomes:
        if budget.feasible(o):
            assert o.energy_saved_j <= res.best.energy_saved_j
    # an impossible budget yields best=None (noop excluded by its own bound)
    res2 = search_frontier(store, budget=PenaltyBudget(max_penalty_s=-0.0),
                           include_noop=False, max_evals=40, max_rounds=1,
                           min_job_duration_s=0.0)
    assert all(not PenaltyBudget(max_penalty_s=-0.0).feasible(o)
               or o.penalty_s == 0.0 for o in res2.frontier.outcomes)


def test_search_deterministic_and_workers_bit_identical(store_dir):
    store = _store(store_dir)
    a = search_frontier(store, min_job_duration_s=0.0)
    b = search_frontier(store, min_job_duration_s=0.0)
    assert frontier_to_dict(a.frontier) == frontier_to_dict(b.frontier)
    c = search_frontier(store, workers=2, min_job_duration_s=0.0)
    assert frontier_to_dict(a.frontier) == frontier_to_dict(c.frontier)
    assert a.knee.params == c.knee.params
    assert a.n_evals == c.n_evals


def test_search_tracks_dense_sweep_at_the_knee(store_dir):
    """The acceptance property at test scale: the searched front's
    achievable saving at its knee penalty is within tolerance of (or better
    than) the dense 200-config sweep's at the same operating point."""
    store = _store(store_dir)
    res = search_frontier(store, families=default_families(composites=False),
                          min_job_duration_s=0.0)
    dense = run_sweep(store, min_job_duration_s=0.0)
    at_knee_dense = achievable_saving(dense.outcomes, res.knee.penalty_s)
    assert res.knee.saved_fraction >= at_knee_dense - 0.02
    assert res.n_evals <= 100        # <= 50% of the 200-config dense grid


# --------------------------------------------------------------------------- #
# knee detection
# --------------------------------------------------------------------------- #
def test_find_knee_picks_the_elbow():
    def out(saved, pen):
        from repro.whatif import PolicyOutcome
        return PolicyOutcome(
            name="x", params={}, n_jobs=1, baseline_energy_j=100.0,
            counterfactual_energy_j=100.0 - saved, energy_saved_j=saved,
            saved_fraction=saved / 100.0, penalty_s=pen,
            penalty_fraction=pen / 100.0, wake_events=0, downscale_events=0,
            throttled_time_s=0.0, exec_idle_energy_fraction_baseline=0.0,
            exec_idle_energy_fraction_cf=0.0, per_job_saved_fraction=(),
            per_job_penalty_s=())
    # a sharp elbow at (10, 9): near-vertical rise then a flat tail
    outcomes = [out(0.0, 0.0), out(5.0, 4.0), out(9.0, 10.0),
                out(9.5, 50.0), out(10.0, 100.0)]
    knee = find_knee(outcomes)
    assert knee.energy_saved_j == 9.0
    # dominated points never win
    outcomes.append(out(1.0, 90.0))
    assert find_knee(outcomes).energy_saved_j == 9.0
    # degenerate: single point
    assert find_knee([out(3.0, 1.0)]).energy_saved_j == 3.0
    with pytest.raises(ValueError):
        find_knee([])


def test_achievable_saving():
    store = None
    from repro.whatif import PolicyOutcome

    def out(saved_frac, pen):
        return PolicyOutcome(
            name="x", params={}, n_jobs=1, baseline_energy_j=1.0,
            counterfactual_energy_j=1.0, energy_saved_j=saved_frac,
            saved_fraction=saved_frac, penalty_s=pen, penalty_fraction=0.0,
            wake_events=0, downscale_events=0, throttled_time_s=0.0,
            exec_idle_energy_fraction_baseline=0.0,
            exec_idle_energy_fraction_cf=0.0,
            per_job_saved_fraction=(), per_job_penalty_s=())
    os_ = [out(0.1, 1.0), out(0.3, 5.0), out(0.2, 2.0)]
    assert achievable_saving(os_, 2.5) == 0.2
    assert achievable_saving(os_, 0.5) == 0.0
    assert achievable_saving(os_, 10.0) == 0.3


# --------------------------------------------------------------------------- #
# family/axis validation and custom families
# --------------------------------------------------------------------------- #
def test_axis_validation():
    with pytest.raises(ValueError, match="lo must be < hi"):
        ContinuousAxis("x", 2.0, 1.0, coarse=(1.5,))
    with pytest.raises(ValueError, match="log axis"):
        ContinuousAxis("x", 0.0, 1.0, coarse=(0.5,), log=True)
    with pytest.raises(ValueError, match="outside"):
        ContinuousAxis("x", 1.0, 2.0, coarse=(3.0,))
    with pytest.raises(ValueError, match="non-empty"):
        CategoricalAxis("m", ())
    with pytest.raises(ValueError, match="max_evals"):
        search_frontier(None, max_evals=0)
    with pytest.raises(ValueError, match=">= 0"):
        PenaltyBudget(max_penalty_s=-1.0)


def test_custom_single_family_search(store_dir):
    store = _store(store_dir)
    fam = PolicyFamily(
        name="caps",
        axes=(ContinuousAxis("cap_fraction", 0.3, 0.9,
                             coarse=(0.3, 0.9), resolution=0.01),),
        build=lambda pt: PowerCapPolicy(cap_fraction=pt["cap_fraction"]))
    res = search_frontier(store, families=[fam], max_evals=20,
                          min_job_duration_s=0.0)
    assert res.n_evals <= 20
    names = {o.name for o in res.frontier.outcomes}
    assert names == {"noop", "powercap"}
    # the midpoint refinement actually subdivided the cap axis
    caps = sorted(o.params["cap_fraction"]
                  for o in res.frontier.outcomes if o.name == "powercap")
    assert len(caps) > 2
    assert any(0.3 < c < 0.9 for c in caps)
    # coarse grids exceeding the budget are rejected up front
    with pytest.raises(ValueError, match="coarse grids"):
        search_frontier(store, families=[fam], max_evals=2)

"""Give multi-device tests a few host devices WITHOUT touching the dry-run's
512-device setting (smoke tests and benches must see a small count)."""
import os

# must run before jax initializes; 4 host devices cover the 2-way mesh tests
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

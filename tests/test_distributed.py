"""Distribution tests: sharding specs, MoE EP vs dense oracle, compression,
checkpoint elastic restore, cluster sim pipeline."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.distributed.compression import (compressed_psum, dequantize_int8,
                                           quantize_int8)
from repro.distributed.context import DistContext
from repro.models import api


def test_param_specs_cover_every_leaf():
    dist = DistContext()  # disabled: raw specs
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        abstract = api.abstract_params(cfg, ep_size=16)
        specs = shd.param_specs(abstract, dist)
        n_leaves = len(jax.tree.leaves(abstract))
        n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves, arch


def test_quantize_roundtrip_small_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.01, (1000,)).astype(np.float32))
    q, scale, shape = quantize_int8(x)
    back = dequantize_int8(q, scale, shape)
    err = float(jnp.max(jnp.abs(back - x)))
    assert err <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-9


def test_compressed_psum_matches_exact_sum():
    """2-'pod' reduction through int8 + EF approximates the exact mean; the
    error-feedback residual equals the quantization error."""
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 host devices (run under dryrun XLA_FLAGS)")
    mesh = jax.make_mesh((2,), ("pod",))
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(0, 1e-3, (2, 512)).astype(np.float32))

    def body(x, e):
        s, new_e = compressed_psum({"g": x}, "pod", {"g": e})
        return s["g"], new_e["g"]

    out, err = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("pod"), P("pod")), out_specs=P("pod"),
        check_vma=False))(g, jnp.zeros_like(g))
    exact = jnp.sum(g, axis=0)
    got = out[0]  # both pod shards hold the same sum
    assert float(jnp.max(jnp.abs(got - exact))) < 5e-5


def test_moe_ep_matches_dense_oracle():
    """Expert-parallel dispatch == dense all-experts compute (high capacity,
    2-way model mesh)."""
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 host devices")
    from repro.models import moe as moe_mod
    cfg = get_smoke_config("granite-moe-3b-a800m")
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    dist = DistContext(mesh=mesh, batch_axes=("data",), model_axis="model")
    key = jax.random.PRNGKey(0)
    p = moe_mod.init_moe_ffn(key, cfg, ep_size=2, n_layers=1)
    p = jax.tree.map(lambda a: a[0], p)  # single layer slice
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)

    dense_out, dense_aux = moe_mod.moe_ffn_dense(x, p, cfg)
    ep_out, ep_aux = jax.jit(
        lambda x: moe_mod.moe_ffn_ep(x, p, cfg, dist, capacity_factor=8.0))(x)
    np.testing.assert_allclose(np.asarray(ep_out, np.float32),
                               np.asarray(dense_out, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_checkpoint_elastic_reshard():
    """Save on 1 device, restore onto a 2-device mesh with shardings."""
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 host devices")
    from repro.train import checkpoint as ckpt
    from repro.train.optimizer import adamw
    cfg = get_smoke_config("qwen1.5-0.5b")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw()
    state = opt.init(params)
    mesh = jax.make_mesh((2, 1), ("data", "model"))
    dist = DistContext(mesh=mesh, batch_axes=("data",), model_axis="model")
    p_specs = shd.param_specs(params, dist)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, params, state)
        p2, s2, step = ckpt.restore(
            d, params, state,
            param_shardings=shd.named(dist, p_specs),
            opt_shardings=None)
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), rtol=1e-2,
                                       atol=1e-2)


def test_cluster_sim_pipeline_end_to_end():
    """Small cluster sample through the full analysis pipeline."""
    from repro.cluster import generate_cluster
    from repro.telemetry import analyze_fleet
    cs = generate_cluster(n_devices=6, horizon_s=2 * 3600, seed=3)
    fa = analyze_fleet(cs.frame, min_job_duration_s=1800)
    assert len(fa.jobs) >= 1
    assert 0.0 < fa.in_execution_time_fraction < 0.6
    assert fa.in_execution_energy_fraction < fa.in_execution_time_fraction

"""Telemetry pipeline tests: sampler, attribution, storage, clustering."""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.attribution import attribute_causes, extract_pre_idle_windows
from repro.core.clustering import density_cluster
from repro.core.power_model import SimulatedDevice, get_platform
from repro.core.states import DeviceState
from repro.telemetry import (RuntimeSampler, TelemetryFrame, analyze_fleet,
                             analyze_job, TelemetryStore, tail_share)


def make_sampler():
    return RuntimeSampler(SimulatedDevice(get_platform("tpu_v5e")), job_id=3)


def test_sampler_emits_one_row_per_second():
    s = make_sampler()
    s.load_program()
    s.busy(3.5, compute_util=0.9)
    s.idle(6.5)
    f = s.frame()
    assert len(f) == 10
    assert np.all(np.diff(f["timestamp"]) == 1.0)


def test_sampler_states_roundtrip():
    """Busy/idle phases pushed through the sampler are recovered by the
    classifier (end-to-end: runtime -> telemetry -> analysis)."""
    s = make_sampler()
    s.load_program()
    for _ in range(3):
        s.busy(4.0, compute_util=0.8, hbm_util=0.5)
        s.idle(8.0)
    s.unload_program()
    s.idle(5.0)
    ja = analyze_job(s.frame(), 3)
    assert len(ja.intervals) == 3
    assert ja.breakdown.time_s[DeviceState.DEEP_IDLE] >= 4
    # idle power above deep idle (the paper's core observation)
    f = s.frame()
    idle_power = f["power"][(f["program_resident"] == 1) & (f["sm"] < 5)]
    deep_power = f["power"][f["program_resident"] == 0]
    assert idle_power.mean() > 1.5 * deep_power.mean()


def test_sampler_drain_to_store_appends_shards():
    """Long-replay plumbing: drain() output lands in TelemetryStore.append
    shards whose concatenation equals the undrained frame, and last_row()
    survives the drain (controllers keep polling O(1) mid-replay)."""
    ref = make_sampler()
    ref.load_program()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        s = make_sampler()
        s.load_program()
        for sampler in (ref, s):
            sampler.busy(4.0, compute_util=0.8, hbm_util=0.5)
        assert s.drain_to(store) == 4
        last = s.last_row()
        assert last is not None and last["timestamp"] == 3.0
        for sampler in (ref, s):
            sampler.idle(6.0)
        assert s.drain_to(store) == 6
        assert s.drain_to(store) == 0          # empty drain appends nothing
        store.save_manifest()
        assert len(store.manifest["shards"]) == 2
        back = store.read_all()
    full = ref.frame()
    assert len(back) == len(full) == 10
    for f in full.columns:
        a, b = full[f], back[f]
        assert np.array_equal(a, b, equal_nan=(a.dtype.kind == "f")), f


def test_phase_signal_noise_block_bit_identical_to_per_field_draws():
    """The simulator's one-normal-block-per-phase optimization must consume
    the rng bitstream exactly like the legacy per-field ``normal(0, s, n)``
    calls, so seeded cluster output never changes."""
    from repro.cluster import jobgen
    from repro.cluster.simulator import _phase_signals
    from repro.core.power_model import PLATFORMS

    def legacy_noise_fields(rng, plat, kind, util, n):
        """Per-field draw order of the pre-batched implementation."""
        if kind == "deep":
            return {"power": plat.deep_idle_w + rng.normal(0.0, 1.0, n),
                    "cpu_util": np.clip(5 + rng.normal(0.0, 2.0, n), 0, 100)}
        if kind == "idle":
            sm = np.clip(rng.uniform(0, 2.5, n), 0, 4.9)
            dram = np.clip(rng.uniform(0, 2.0, n), 0, 4.9)
            return {"sm": sm, "dram": dram,
                    "power": plat.exec_idle_w + rng.normal(0.0, 3.0, n),
                    "cpu_util": np.clip(8 + rng.normal(0.0, 4.0, n), 0, 100)}
        return {"sm": np.clip(100 * util + rng.normal(0.0, 6.0, n), 6, 100),
                "tensor": np.clip(85 * util + rng.normal(0.0, 6.0, n), 0, 100),
                "dram": np.clip(70 * util + rng.normal(0.0, 8.0, n), 5.5, 100),
                "power": np.clip(plat.power_w(util) + rng.normal(0.0, 8.0, n),
                                 plat.exec_idle_w, plat.tdp_w),
                "cpu_util": np.clip(30 + rng.normal(0.0, 8.0, n), 0, 100)}

    plat = PLATFORMS["l40s"]
    for kind, util in (("deep", 0.0), ("idle", 0.0), ("active", 0.7)):
        # n=40 keeps the active branch dip-free (dips need n > 45) and
        # cause="" skips the tail signature, so only the (unchanged)
        # dip-slot/tail-length draws follow the noise block
        phase = jobgen.Phase(kind, 40, util=util, cause="")
        r_new, r_old = np.random.default_rng(13), np.random.default_rng(13)
        cols, _, _ = _phase_signals(r_new, phase, plat, 40)
        ref = legacy_noise_fields(r_old, plat, kind, util, 40)
        for f, expected in ref.items():
            assert np.array_equal(cols[f], expected), (kind, f)


def test_store_append_derives_day_label():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        frame = TelemetryFrame.from_rows([
            {"timestamp": 86400.0 * 2 + 5.0, "job_id": 1, "device_id": 0,
             "hostname": 0, "program_resident": 1, "power": 100.0}])
        store.append(frame, host="h3")
        assert store.manifest["shards"][0]["day"] == 2
        assert store.manifest["shards"][0]["host"] == "h3"
        assert store.append(TelemetryFrame({}), host="h3") is None
        assert len(store.manifest["shards"]) == 1


def test_storage_roundtrip():
    s = make_sampler()
    s.load_program()
    s.busy(5.0)
    frame = s.frame()
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        store.write_shard(frame, host="h0", day=0)
        store.write_shard(frame, host="h1", day=0)
        assert store.total_rows == 2 * len(frame)
        back = store.read_all(hosts=["h0"])
        assert len(back) == len(frame)
        np.testing.assert_allclose(back["power"], frame["power"])


def test_analyze_fleet_filters_short_jobs():
    rows = []
    for jid, dur in ((1, 100), (2, 400)):
        for t in range(dur):
            rows.append({"timestamp": float(t), "job_id": jid, "device_id": jid,
                         "hostname": 0, "program_resident": 1, "sm": 50.0,
                         "power": 200.0})
    frame = TelemetryFrame.from_rows(rows)
    fa = analyze_fleet(frame, min_job_duration_s=200)
    assert [j.job_id for j in fa.jobs] == [2]


# --------------------------------------------------------------------------- #
# pre-idle attribution (§4.5)
# --------------------------------------------------------------------------- #
def test_attribution_recovers_causes():
    rng = np.random.default_rng(0)
    states, sig = [], {k: [] for k in ("sm", "dram", "pcie", "nic", "nvlink", "cpu")}
    causes = (["pcie"] * 30) + (["nic"] * 15) + (["compute"] * 25)
    rng.shuffle(causes)
    for cause in causes:
        # active burst with a cause-signature tail, then idle interval
        for phase, n in (("act", 8), ("tail", 4), ("idle", 7)):
            for _ in range(n):
                states.append(int(DeviceState.ACTIVE if phase != "idle"
                                  else DeviceState.EXECUTION_IDLE))
                sig["sm"].append(60.0 if phase != "idle" else 1.0)
                sig["dram"].append(40.0 if phase != "idle" else 0.5)
                sig["pcie"].append(5.0 if (phase == "tail" and cause == "pcie") else 0.0)
                sig["nic"].append(4.0 if (phase == "tail" and cause == "nic") else 0.0)
                sig["nvlink"].append(0.0)
                sig["cpu"].append(30.0)
    states = np.array(states)
    signals = {k: np.array(v) for k, v in sig.items()}
    windows = extract_pre_idle_windows(states, signals, window_s=10)
    assert len(windows) == len(causes)
    result = attribute_causes(windows, min_cluster_size=8)
    assert abs(result.category_shares["pcie_heavy"] - 30 / 70) < 0.1
    assert abs(result.category_shares["nic_heavy"] - 15 / 70) < 0.1
    assert abs(result.category_shares["compute_to_idle"] - 25 / 70) < 0.1


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_clustering_labels_cover_all_points(seed):
    rng = np.random.default_rng(seed)
    x = np.vstack([rng.normal(0, 0.3, (40, 4)),
                   rng.normal(5, 0.3, (40, 4))])
    res = density_cluster(x, min_cluster_size=10)
    assert res.labels.shape == (80,)
    assert res.n_clusters >= 2
    # clusters separate the two blobs
    first = res.labels[:40]
    second = res.labels[40:]
    lab1 = np.bincount(first[first >= 0]).argmax()
    lab2 = np.bincount(second[second >= 0]).argmax()
    assert lab1 != lab2


def test_tail_share():
    fr = np.array([0.05, 0.15, 0.3, 0.6])
    assert tail_share(fr, 0.1) == pytest.approx(0.75)
    assert tail_share(fr, 0.5) == pytest.approx(0.25)

"""Serving substrate tests: DES, traces, engine, perf model."""
import dataclasses

import jax
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_smoke_config
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.power_model import get_platform
from repro.models import api
from repro.serving.des import simulate_pool
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.latency import LatencyStats, Request, inter_arrival_cdf
from repro.serving.perf_model import LLAMA13B_L40S, PerfModel, from_roofline
from repro.traces import TRACES, generate_trace

PLAT = get_platform("l40s")


def small_trace(n=20, gap=5.0, work=1.0):
    perf = LLAMA13B_L40S
    return [Request(req_id=i, arrival_s=i * gap,
                    prompt_tokens=int(perf.prefill_tps * work / 2),
                    output_tokens=int(perf.decode_tps * work / 2))
            for i in range(n)]


def test_all_requests_complete_when_underloaded():
    trace = small_trace(n=10, gap=10.0, work=1.0)
    res = simulate_pool(trace, PLAT, LLAMA13B_L40S, PoolConfig(n_devices=1),
                        duration_s=200.0)
    assert res.latency.n == 10
    assert res.latency.p95_s >= 1.0


def test_energy_decreases_with_consolidation():
    """§5.1: consolidating onto fewer devices cuts energy, raises latency."""
    spec = TRACES["azure_code"]
    trace = generate_trace(spec, 600.0, n_devices=8, seed=0)
    results = {}
    for n_active, policy in ((8, PoolPolicy.BALANCED), (2, PoolPolicy.CONSOLIDATED)):
        pool = PoolConfig(n_devices=8, policy=policy, n_active=n_active,
                          park_inactive=False)
        results[n_active] = simulate_pool(
            [dataclasses.replace(r) for r in trace], PLAT, LLAMA13B_L40S,
            pool, 600.0)
    assert results[2].energy_j < results[8].energy_j
    assert results[2].latency.p95_s > results[8].latency.p95_s


def test_controller_reduces_power_increases_latency():
    """§5.3: Algorithm 1 cuts average power at a latency cost."""
    spec = TRACES["azure_code"]
    trace = generate_trace(spec, 900.0, 1, seed=1)
    base = simulate_pool([dataclasses.replace(r) for r in trace], PLAT,
                         LLAMA13B_L40S, PoolConfig(n_devices=1), 900.0)
    ctl = simulate_pool([dataclasses.replace(r) for r in trace], PLAT,
                        LLAMA13B_L40S, PoolConfig(n_devices=1), 900.0,
                        controller_cfg=ControllerConfig(mode=DownscaleMode.SM_AND_MEM))
    assert ctl.avg_power_w < base.avg_power_w * 0.9
    assert ctl.latency.p95_s >= base.latency.p95_s


@given(st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_des_energy_time_consistency(seed):
    spec = TRACES["qwen_chat"]
    trace = generate_trace(spec, 300.0, 1, seed=seed)
    res = simulate_pool(trace, PLAT, LLAMA13B_L40S, PoolConfig(n_devices=1), 300.0)
    # fractions bounded; avg power within platform envelope
    assert 0 <= res.exec_idle_time_fraction <= 1
    assert 0 <= res.exec_idle_energy_fraction <= 1
    assert PLAT.deep_idle_w <= res.avg_power_w <= PLAT.tdp_w
    # exec-idle energy share below time share (idle power < active power)
    if 0 < res.exec_idle_time_fraction < 1:
        assert res.exec_idle_energy_fraction <= res.exec_idle_time_fraction


def test_trace_generators_deterministic():
    a = generate_trace(TRACES["azure_chat"], 600.0, 1, seed=7)
    b = generate_trace(TRACES["azure_chat"], 600.0, 1, seed=7)
    assert [(r.arrival_s, r.prompt_tokens) for r in a] == \
        [(r.arrival_s, r.prompt_tokens) for r in b]


def test_inter_arrival_cdf():
    reqs = [Request(req_id=i, arrival_s=float(i * 2), prompt_tokens=1,
                    output_tokens=1, device=0) for i in range(5)]
    gaps = inter_arrival_cdf(reqs)
    np.testing.assert_allclose(gaps, [2.0] * 4)


def test_perf_model_roofline_derivation():
    cfg = get_smoke_config("gemma-2b")
    pm = from_roofline(cfg, peak_tflops=197.0, hbm_gbps=819.0,
                       n_params=2_500_000_000)
    assert pm.decode_tps > 100          # batched decode
    assert pm.prefill_tps > pm.decode_tps


def test_des_store_spill_matches_monolithic_frame():
    """simulate_pool(store=...) spills telemetry into shards instead of
    materializing the full frame; the shards concatenate back to exactly
    the monolithic telemetry."""
    import tempfile

    from repro.telemetry import TelemetryStore
    trace = small_trace(n=15, gap=5.0, work=0.5)
    mono = simulate_pool(list(trace), PLAT, LLAMA13B_L40S,
                         PoolConfig(n_devices=2), duration_s=120.0)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        streamed = simulate_pool(list(trace), PLAT, LLAMA13B_L40S,
                                 PoolConfig(n_devices=2), duration_s=120.0,
                                 store=store, drain_every_s=30.0)
        assert len(streamed.telemetry) == 0
        assert len(store.manifest["shards"]) >= 4
        back = store.read_all()
    assert streamed.energy_j == mono.energy_j
    assert len(back) == len(mono.telemetry)
    for f in mono.telemetry.columns:
        a, b = mono.telemetry[f], back[f]
        assert np.array_equal(a, b, equal_nan=(a.dtype.kind == "f")), f


# --------------------------------------------------------------------------- #
# live engine (integration)
# --------------------------------------------------------------------------- #
def test_engine_serves_requests_end_to_end():
    import tempfile

    from repro.telemetry import TelemetryStore
    cfg = get_smoke_config("qwen1.5-0.5b")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, EngineConfig(
        n_slots=2, max_seq_len=64, prefill_bucket=16, max_new_tokens=4))
    rng = np.random.default_rng(0)
    reqs = [Request(req_id=i, arrival_s=i * 0.3, prompt_tokens=8,
                    output_tokens=4) for i in range(5)]
    prompts = {i: rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
               for i in range(5)}
    with tempfile.TemporaryDirectory() as d:
        # telemetry drains to storage shards (drain_every_s=2 allows mid-run
        # drains), so long replays never hold the full frame; shard count is
        # load-dependent (empty drains append nothing), >= 1 is guaranteed
        # by the final flush
        store = TelemetryStore(d)
        stats = eng.run(reqs, prompts, store=store, drain_every_s=2.0)
        assert stats.n == 5
        assert len(eng.sampler.frame()) == 0      # drained, not retained
        assert len(store.manifest["shards"]) >= 1
        rows = store.read_all()
    assert len(rows) > 0
    assert (rows["job_id"] == 1).all()
    assert np.all(np.diff(rows["timestamp"]) == 1.0)


def test_engine_telemetry_shows_idle_between_bursts():
    cfg = get_smoke_config("qwen1.5-0.5b")
    params = api.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, EngineConfig(
        n_slots=2, max_seq_len=64, prefill_bucket=16, max_new_tokens=2))
    eng.sampler.load_program()
    eng.decode_tick()                 # no requests -> exec-idle second
    eng.decode_tick()
    f = eng.sampler.frame()
    assert len(f) >= 2
    assert (f["sm"] < 5).all()
    assert (f["power"] > get_platform("tpu_v5e").deep_idle_w).all()

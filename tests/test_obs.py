"""Observability layer contract: default-off, bit-identical results,
cross-process span reassembly, stable histogram edges, parseable Prometheus
exposition, and the >= 15 distinct ``repro_*`` metrics acceptance gate.
"""
import json
import tempfile
import urllib.error
import urllib.request

import pytest

import repro.obs as obs
from repro.cluster import generate_cluster
from repro.telemetry import TelemetryStore
from repro.telemetry.pipeline import analyze_store
from repro.whatif import (default_policy_grid, frontier_to_dict, run_sweep,
                          search_frontier)


@pytest.fixture(scope="module")
def store_dir():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=8, horizon_s=2700, seed=3,
                         store=store, shard_s=900)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        yield d


@pytest.fixture()
def clean_obs():
    """Isolate the global obs state; leave obs disabled and empty after."""
    prev = obs.enabled()
    obs.disable()
    obs.reset()
    yield
    obs.enable() if prev else obs.disable()
    obs.reset()


# --------------------------------------------------------------------------- #
# registry basics
# --------------------------------------------------------------------------- #
def test_disabled_helpers_record_nothing(clean_obs):
    obs.counter("repro_x_total")
    obs.gauge("repro_x", 1.0)
    obs.observe("repro_x_seconds", 0.5)
    with obs.span("nothing"):
        pass
    assert obs.REGISTRY.names() == []
    assert obs.spans() == []


def test_counter_gauge_histogram_semantics(clean_obs):
    obs.enable()
    obs.counter("repro_c_total", 2.0, path="a")
    obs.counter("repro_c_total", 3.0, path="a")
    obs.counter("repro_c_total", 1.0, path="b")
    fam = obs.REGISTRY.family("repro_c_total")
    assert {dict(k)["path"]: m.value
            for k, m in fam.metrics.items()} == {"a": 5.0, "b": 1.0}

    obs.gauge("repro_g", 2.0)
    obs.gauge("repro_g", 7.0)
    assert obs.REGISTRY.gauge("repro_g").value == 7.0

    obs.observe("repro_h_seconds", 0.01)
    obs.observe("repro_h_seconds", 1e9)        # lands in the +Inf slot
    h = obs.REGISTRY.histogram("repro_h_seconds")
    assert h.count == 2 and h.counts[-1] == 1

    with pytest.raises(ValueError):
        obs.REGISTRY.counter("repro_c_total").inc(-1.0)
    with pytest.raises(ValueError):
        obs.REGISTRY.gauge("repro_c_total")    # kind conflict
    with pytest.raises(ValueError):
        obs.REGISTRY.counter("not a name!")


def test_histogram_edges_pinned_and_mergeable(clean_obs):
    edges = obs.default_buckets()
    assert edges == tuple(10.0 ** (k / 3.0) for k in range(-18, 13))
    assert len(edges) == 31
    # bit-stable: a second computation and a fresh Histogram agree exactly,
    # which is what lets worker histograms merge bucket-wise
    assert obs.Histogram().edges == edges

    obs.enable()
    obs.observe("repro_m_seconds", 0.5)
    dump = obs.REGISTRY.dump()
    obs.REGISTRY.merge(dump)                   # self-merge doubles counts
    h = obs.REGISTRY.histogram("repro_m_seconds")
    assert h.count == 2 and h.sum == 1.0


# --------------------------------------------------------------------------- #
# spans
# --------------------------------------------------------------------------- #
def test_span_nesting_single_process(clean_obs):
    obs.enable()
    with obs.span("outer", stage="x"):
        with obs.span("inner"):
            pass
        with obs.span("inner"):
            pass
    recs = obs.spans()
    assert [r.name for r in recs] == ["inner", "inner", "outer"]
    outer = recs[-1]
    assert outer.parent_id is None and outer.attrs == {"stage": "x"}
    assert all(r.parent_id == outer.span_id for r in recs[:2])
    roots = obs.span_tree(recs)
    assert len(roots) == 1 and len(roots[0].children) == 2


def test_span_jsonl_round_trip(clean_obs, tmp_path):
    obs.enable()
    with obs.span("root"):
        with obs.span("child", k=1):
            pass
    path = obs.dump_spans_jsonl(tmp_path / "spans.jsonl")
    recs = obs.load_spans_jsonl(path)
    assert recs == obs.spans()
    roots = obs.span_tree(recs)
    assert [n.span.name for n in roots] == ["root"]
    assert [c.span.name for c in roots[0].children] == ["child"]
    # every line is a flat JSON object (consumable without this package)
    for line in path.read_text().splitlines():
        assert isinstance(json.loads(line), dict)


def test_worker_spans_reassemble_across_processes(store_dir, clean_obs):
    obs.enable()
    store = TelemetryStore(store_dir)
    analyze_store(store, workers=2, compact=False)  # exercise the row pool
    recs = obs.spans()
    by_name = {}
    for r in recs:
        by_name.setdefault(r.name, []).append(r)
    # the pool fan-out produced spans in >= 2 worker processes, plus ours
    assert len({r.pid for r in recs}) >= 2
    parts = by_name["analyze.partition"]
    assert len(parts) >= 2
    # every worker span re-parents onto the parent-process stage span
    root = by_name["analyze_store"][0]
    assert all(p.parent_id == root.span_id for p in parts)
    ids = {r.span_id for r in recs}
    assert all(r.parent_id in ids for r in recs if r.parent_id)
    # and the worker metrics merged home
    assert obs.REGISTRY.counter("repro_analyze_rows_total").value > 0


# --------------------------------------------------------------------------- #
# bit-identity: the production contract
# --------------------------------------------------------------------------- #
def test_sweep_and_search_bit_identical_obs_on_off(store_dir, clean_obs):
    store = TelemetryStore(store_dir)
    grid = default_policy_grid(dense=False)[:10]

    f_off = run_sweep(store, grid, min_job_duration_s=0.0)
    r_off = search_frontier(store, max_evals=40, min_job_duration_s=0.0)
    obs.enable()
    f_on = run_sweep(store, grid, min_job_duration_s=0.0)
    r_on = search_frontier(store, max_evals=40, min_job_duration_s=0.0)

    assert frontier_to_dict(f_on) == frontier_to_dict(f_off)
    # frontier dicts include the convergence trace — identical too
    assert frontier_to_dict(r_on.frontier) == frontier_to_dict(r_off.frontier)
    assert r_on.frontier.trace and r_off.frontier.trace


def test_search_trace_is_deterministic_replay_data(store_dir, clean_obs):
    store = TelemetryStore(store_dir)
    res = search_frontier(store, max_evals=40, min_job_duration_s=0.0)
    assert len(res.frontier.trace) == res.n_evals
    for i, t in enumerate(res.frontier.trace):
        assert t["i"] == i
        assert set(t) == {"i", "round", "family", "saved_fraction",
                          "penalty_s"}
    # eval order: trace rows map 1:1 onto the frontier's outcomes
    assert [t["saved_fraction"] for t in res.frontier.trace] == \
        [o.saved_fraction for o in res.frontier.outcomes]


# --------------------------------------------------------------------------- #
# acceptance gate: the instrumented pipeline emits a wide metric surface
# --------------------------------------------------------------------------- #
def test_pipeline_emits_at_least_15_repro_metrics(store_dir, clean_obs):
    obs.enable()
    store = TelemetryStore(store_dir)
    analyze_store(store)
    run_sweep(store, default_policy_grid(dense=False)[:10],
              min_job_duration_s=0.0)
    search_frontier(store, max_evals=40, min_job_duration_s=0.0)
    names = [n for n in obs.REGISTRY.names() if n.startswith("repro_")]
    assert len(names) >= 15, names
    stages = {"analyze": "repro_analyze_", "ir": "repro_ir_",
              "replay": "repro_replay_", "search": "repro_search_"}
    for stage, prefix in stages.items():
        assert any(n.startswith(prefix) for n in names), (stage, names)

    text = obs.render_prometheus()
    assert obs.lint_exposition(text) == []
    # the exposition exposes every family recorded above
    for n in names:
        assert n in text


# --------------------------------------------------------------------------- #
# exposition + endpoint
# --------------------------------------------------------------------------- #
def test_prometheus_render_lints_clean(clean_obs):
    obs.enable()
    obs.counter("repro_t_total", 2.0, path="a b")   # label value with space
    obs.gauge("repro_t", -1.5)
    obs.observe("repro_t_seconds", 0.02)
    text = obs.render_prometheus()
    assert obs.lint_exposition(text) == []
    assert '# TYPE repro_t_seconds histogram' in text
    assert 'le="+Inf"' in text


def test_linter_rejects_malformed_expositions():
    assert obs.lint_exposition("repro_x 1\n")       # sample before TYPE
    assert obs.lint_exposition("# TYPE repro_x counter\nrepro_x one\n")
    assert obs.lint_exposition(
        "# TYPE repro_x histogram\n"
        'repro_x_bucket{le="1"} 1\n'                # no +Inf bucket
        "repro_x_count 1\n")
    assert obs.lint_exposition(
        "# TYPE repro_x histogram\n"
        'repro_x_bucket{le="+Inf"} 1\n'
        "repro_x_count 2\n")                        # +Inf != _count


def test_http_metrics_endpoint(clean_obs):
    obs.enable()
    obs.counter("repro_http_total", 3.0)
    server = obs.start_http_server(port=0)
    try:
        port = server.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as resp:
            assert resp.status == 200
            body = resp.read().decode()
        assert "repro_http_total 3" in body
        assert obs.lint_exposition(body) == []
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
    finally:
        server.shutdown()


# --------------------------------------------------------------------------- #
# TRACE_COUNTS migration (jax backend)
# --------------------------------------------------------------------------- #
def test_trace_counts_is_registry_backed_mapping(clean_obs):
    import repro.whatif.backend as B
    assert dict(B.TRACE_COUNTS) == {}
    B._mark_trace("downscale")
    B._mark_trace("downscale")
    B._mark_trace("powercap")
    assert dict(B.TRACE_COUNTS) == {"downscale": 2, "powercap": 1}
    assert B.TRACE_COUNTS["downscale"] == 2
    assert B.TRACE_COUNTS.get("integrate", 0) == 0
    assert sorted(B.TRACE_COUNTS) == ["downscale", "powercap"]
    # always-on: records with obs disabled, straight into the registry
    assert not obs.enabled()
    fam = obs.REGISTRY.family("repro_backend_jit_traces_total")
    assert fam is not None and fam.kind == "counter"

"""Run-level telemetry IR (ISSUE 5): round-trip, cache invalidation, and
compact-vs-row equivalence.

The load-bearing contract: a compact (run-IR) replay must report the SAME
time/count metrics as the row-exact reference — per-state durations, event
counts, throttled time, decision-derived outcomes, bit for bit — and
energies/penalties within 1e-9 relative (the per-run power sums are exact
partial sums of the same samples, only the float summation order differs).
"""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.cluster import generate_cluster
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.energy import BatchedStreamingIntegrator
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.states import ClassifierConfig, classify_series
from repro.telemetry import TelemetryStore
from repro.telemetry.records import TelemetryFrame
from repro.whatif import (CompositePolicy, DownscalePolicy, IRConfig,
                          IRUnsupportedError, NoOpPolicy, ParkingPolicy,
                          PowerCapPolicy, build_ir, default_policy_grid,
                          downscale_trigger_index, evaluate, format_frontier,
                          frontier_to_dict, get_ir, ir_supported,
                          load_sidecar, low_activity_series, run_sweep,
                          save_sidecar, search_frontier, seed_points)
from repro.whatif.policies import low_activity_series  # noqa: F811


@pytest.fixture(scope="module")
def store_dir():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=8, horizon_s=2700, seed=11,
                         store=store, shard_s=700)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        yield d


def _store(store_dir):
    return TelemetryStore(store_dir)


# --------------------------------------------------------------------------- #
# integrator: update_runs == update on the expanded series
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_update_runs_matches_sample_updates(seed):
    rng = np.random.default_rng(seed % 100000)
    n_runs, n_cfg = 200, 3
    states = rng.choice([0, 1, 2], size=n_runs).astype(np.int8)
    lengths = rng.integers(1, 12, size=n_runs)
    energy = rng.normal(100, 30, (n_cfg, n_runs)) * lengths
    ref = BatchedStreamingIntegrator(n_configs=n_cfg, min_duration_s=5.0)
    # expanded per-sample series with each run's energy spread evenly: the
    # run path must bucket identical times and 1e-9-equal energies
    s_exp = np.repeat(states, lengths)
    p_exp = np.repeat(energy / lengths, lengths, axis=1)
    ref.update(s_exp, p_exp)
    ref_bds, ref_ivs = ref.finalize_batch()

    run = BatchedStreamingIntegrator(n_configs=n_cfg, min_duration_s=5.0)
    chunk = int(rng.integers(1, n_runs + 1))
    for s in range(0, n_runs, chunk):
        run.update_runs(states[s:s + chunk], energy[:, s:s + chunk],
                        lengths[s:s + chunk])
    run_bds, run_ivs = run.finalize_batch()
    assert run_ivs == ref_ivs
    for a, b in zip(ref_bds, run_bds):
        assert a.time_s == b.time_s                 # bit-identical
        for k in a.energy_j:
            assert np.isclose(a.energy_j[k], b.energy_j[k],
                              rtol=1e-9, atol=1e-9)


def test_update_runs_rejects_mixing_with_update():
    bi = BatchedStreamingIntegrator(n_configs=1)
    bi.update(np.array([1, 1, 2]), np.array([[1.0, 1.0, 2.0]]))
    with pytest.raises(ValueError, match="update_runs"):
        bi.update_runs(np.array([2]), np.array([[2.0]]), np.array([3]))


# --------------------------------------------------------------------------- #
# IR round-trip: rows -> runs -> rows, and sidecar save/load
# --------------------------------------------------------------------------- #
def test_ir_roundtrips_rows_exactly(store_dir):
    store = _store(store_dir)
    config = IRConfig()
    ir = build_ir(store, config)
    assert ir.n_runs < ir.n_rows            # the corpus actually compacts
    frame = store.read_all()
    seen = 0
    for key, seg in frame.group_streams():
        if key[0] < 0:
            continue
        stream = ir.streams[key]
        states_ref = classify_series(
            seg["program_resident"].astype(bool), seg.activity_pct(),
            seg.comm_gbs(), config.classifier)
        low_ref = low_activity_series(seg, config.low_config())
        states, low = stream.expand()
        np.testing.assert_array_equal(states, states_ref)
        np.testing.assert_array_equal(low, low_ref)
        np.testing.assert_array_equal(stream.power, seg["power"])
        np.testing.assert_array_equal(stream.ts(), seg["timestamp"])
        # runs are maximal: re-encoding the expansion reproduces the table
        code = states.astype(np.int16) * 2 + low
        assert np.count_nonzero(np.diff(code)) + 1 == stream.n_runs
        # per-run power sums are partial sums of exactly these samples
        # (a run spanning shard boundaries accumulates per shard, so the
        # association — not the sample set — may differ from one reduceat)
        off = stream.run_offsets()
        np.testing.assert_allclose(
            stream.power_sum,
            np.add.reduceat(stream.power, off[:-1]), rtol=1e-12)
        seen += 1
    assert seen == len(ir.streams)


def test_sidecar_roundtrip_is_lossless(store_dir):
    store = _store(store_dir)
    config = IRConfig()
    ir = build_ir(store, config)
    path = save_sidecar(ir, store)
    assert path.exists()
    loaded = load_sidecar(store, config)
    assert loaded is not None
    assert loaded.source_rows == ir.source_rows
    assert set(loaded.streams) == set(ir.streams)
    for key, a in ir.streams.items():
        b = loaded.streams[key]
        assert (a.host_label, a.platform_id, a.ts_first, a.dt_s) == \
            (b.host_label, b.platform_id, b.ts_first, b.dt_s)
        for field in ("state", "low", "length", "power_sum", "power"):
            np.testing.assert_array_equal(getattr(a, field),
                                          getattr(b, field))


def test_sidecar_invalidation(store_dir):
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=2, horizon_s=1200, seed=5, store=store,
                         shard_s=600)
        default_cfg = IRConfig()
        ir = get_ir(store, default_cfg)
        assert load_sidecar(store, default_cfg) is not None
        # a different classifier config hashes to a different sidecar: miss
        permissive = IRConfig(
            classifier=ClassifierConfig(activity_threshold_pct=10.0))
        assert permissive.config_hash() != default_cfg.config_hash()
        assert load_sidecar(store, permissive) is None
        ir2 = get_ir(store, permissive)
        assert ir2.config == permissive
        # both sidecars now coexist under their own manifest keys
        assert len(store.manifest["run_ir"]) == 2
        # appending to the store invalidates (source_rows mismatch)
        generate_cluster(n_devices=1, horizon_s=900, seed=6, store=store,
                         shard_s=900)
        assert load_sidecar(store, default_cfg) is None
        ir3 = get_ir(store, default_cfg)      # rebuilt from the grown store
        assert ir3.source_rows == store.total_rows
        assert ir3.source_rows > ir.source_rows
        assert load_sidecar(store, default_cfg) is not None


def test_irregular_sampling_is_rejected_and_falls_back():
    frame = TelemetryFrame.from_rows([
        {"timestamp": float(t), "job_id": 1, "program_resident": 1,
         "power": 100.0, "sm": 50.0, "hostname": 0, "device_id": 0,
         "platform": 0}
        for t in (0.0, 1.0, 2.0, 5.0, 6.0)])      # gap at t=3,4
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        store.write_shard(frame, host="h0")
        with pytest.raises(IRUnsupportedError):
            build_ir(store, IRConfig())
        # the sweep kernel falls back to the row path instead of raising
        f = run_sweep(store, [NoOpPolicy(), PowerCapPolicy(cap_fraction=0.5)],
                      min_job_duration_s=0.0, min_interval_s=1.0,
                      compact=True)
        assert f.n_runs == 0 and f.n_rows == 5


# --------------------------------------------------------------------------- #
# trigger index: the run-level decision constant
# --------------------------------------------------------------------------- #
def test_downscale_trigger_index_matches_accumulate():
    for eps in (0.5, 1.0, 2.0, 0.3):
        for x in (0.5, 1.0, 3.0, 8.0, 15.0):
            k = downscale_trigger_index(eps, x)
            folds = np.add.accumulate(np.full(64, eps))
            ref = int(np.argmax(folds > x)) if folds[-1] > x else 64
            assert min(k, 64) == ref, (eps, x)


# --------------------------------------------------------------------------- #
# compact == row-exact: time/count metrics bit-identical, energies <= 1e-9
# --------------------------------------------------------------------------- #
EXACT_FIELDS = ("name", "params", "n_jobs", "wake_events",
                "downscale_events", "throttled_time_s")
FLOAT_FIELDS = ("baseline_energy_j", "counterfactual_energy_j",
                "energy_saved_j", "saved_fraction", "penalty_s",
                "penalty_fraction", "exec_idle_energy_fraction_baseline",
                "exec_idle_energy_fraction_cf")


def assert_equivalent(ref, cmp_):
    assert len(ref.outcomes) == len(cmp_.outcomes)
    assert ref.n_rows == cmp_.n_rows
    for a, b in zip(ref.outcomes, cmp_.outcomes):
        for f in EXACT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (a.name, a.params, f)
        for f in FLOAT_FIELDS:
            # 1e-9 relative; atol floors ratios whose numerators are
            # themselves ~1e-12 of the fleet totals (pure float-order noise)
            assert np.isclose(getattr(a, f), getattr(b, f),
                              rtol=1e-9, atol=1e-9), (a.name, a.params, f)
        np.testing.assert_allclose(a.per_job_saved_fraction,
                                   b.per_job_saved_fraction,
                                   rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(a.per_job_penalty_s, b.per_job_penalty_s,
                                   rtol=1e-9, atol=1e-9)


def mixed_grid(rng):
    """Random mix of all supported families plus configs the IR must route
    to the row fallback (foreign thresholds, unsupported composite order)."""
    grid = [NoOpPolicy()]
    for _ in range(int(rng.integers(1, 4))):
        grid.append(DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)),
            cooldown_y_s=float(rng.uniform(1.0, 10.0)),
            interval_eps_s=float(rng.choice([0.5, 1.0, 2.0])),
            mode=rng.choice([DownscaleMode.SM_ONLY, DownscaleMode.SM_AND_MEM]),
        )))
    for _ in range(int(rng.integers(1, 3))):
        n_dev = int(rng.choice([2, 4]))
        grid.append(ParkingPolicy(
            pool=PoolConfig(n_devices=n_dev, policy=PoolPolicy.CONSOLIDATED,
                            n_active=int(rng.integers(1, n_dev))),
            resume_latency_s=float(rng.uniform(2.0, 40.0))))
    for _ in range(int(rng.integers(1, 3))):
        grid.append(PowerCapPolicy(
            cap_fraction=float(rng.uniform(0.3, 0.9))))
    grid.append(CompositePolicy((
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=2),
                      resume_latency_s=float(rng.uniform(2.0, 30.0))),
        DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)))),
    )))
    if rng.random() < 0.5:
        # foreign low-activity thresholds: unsupported, row fallback
        grid.append(DownscalePolicy(config=ControllerConfig(
            activity_threshold=0.03)))
    if rng.random() < 0.5:
        # downscale-then-parking: unsupported composite order, row fallback
        grid.append(CompositePolicy((
            DownscalePolicy(),
            ParkingPolicy(pool=PoolConfig(n_devices=2,
                                          policy=PoolPolicy.CONSOLIDATED,
                                          n_active=1)),
        )))
    order = rng.permutation(len(grid))
    return [grid[i] for i in order]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_compact_matches_row_exact_any_grid_chunking_workers(seed):
    rng = np.random.default_rng(seed % 100000)
    grid = mixed_grid(rng)
    shard_s = int(rng.choice([300, 700, 1500]))
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=6, horizon_s=1500,
                         seed=int(rng.integers(0, 100)),
                         store=store, shard_s=shard_s)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        ref = run_sweep(store, grid, min_job_duration_s=300, compact=False)
        for workers in (1, 2):
            cmp_ = run_sweep(store, grid, workers=workers,
                             min_job_duration_s=300, compact=True)
            assert_equivalent(ref, cmp_)
            assert cmp_.n_runs > 0 and cmp_.n_runs < cmp_.n_rows


def test_compact_supports_min_interval_variants(store_dir):
    store = _store(store_dir)
    grid = [NoOpPolicy(), DownscalePolicy(), PowerCapPolicy(),
            ParkingPolicy(pool=PoolConfig(n_devices=4,
                                          policy=PoolPolicy.CONSOLIDATED,
                                          n_active=1))]
    for min_interval in (1.0, 5.0, 10.0):
        ref = run_sweep(store, grid, min_job_duration_s=0.0,
                        min_interval_s=min_interval, compact=False)
        cmp_ = run_sweep(store, grid, min_job_duration_s=0.0,
                         min_interval_s=min_interval, compact=True)
        assert_equivalent(ref, cmp_)


def test_ir_supported_classification():
    cfg = IRConfig()
    assert ir_supported(NoOpPolicy(), cfg)
    assert ir_supported(DownscalePolicy(), cfg)
    assert ir_supported(PowerCapPolicy(), cfg)
    park = ParkingPolicy(pool=PoolConfig(n_devices=2,
                                         policy=PoolPolicy.CONSOLIDATED,
                                         n_active=1))
    assert ir_supported(park, cfg)
    assert ir_supported(CompositePolicy((park, DownscalePolicy())), cfg)
    assert not ir_supported(CompositePolicy((DownscalePolicy(), park)), cfg)
    assert not ir_supported(DownscalePolicy(config=ControllerConfig(
        activity_threshold=0.03)), cfg)

    class Custom:
        pass
    assert not ir_supported(Custom(), cfg)


def test_frontier_reports_compaction(store_dir):
    store = _store(store_dir)
    f = run_sweep(store, default_policy_grid(dense=False),
                  min_job_duration_s=0.0)
    assert f.n_runs > 0
    assert f.compaction_ratio > 1.0
    text = format_frontier(f, top=3)
    assert "compaction" in text and "runs" in text
    # round-trips through the JSON schema
    from repro.whatif import frontier_from_dict
    assert frontier_from_dict(frontier_to_dict(f)).n_runs == f.n_runs


# --------------------------------------------------------------------------- #
# search: IR reuse and warm start
# --------------------------------------------------------------------------- #
def test_search_compact_matches_row_and_reuses_ir(store_dir):
    store = _store(store_dir)
    row = search_frontier(store, min_job_duration_s=0.0, compact=False)
    cmp_ = search_frontier(store, min_job_duration_s=0.0, compact=True)
    # identical search trajectory: same evals, same knee decision
    assert cmp_.n_evals == row.n_evals
    assert cmp_.knee.params == row.knee.params
    assert np.isclose(cmp_.knee.saved_fraction, row.knee.saved_fraction,
                      rtol=1e-9, atol=1e-12)
    assert cmp_.frontier.n_runs > 0


def test_search_warm_start_seeds_previous_frontier(store_dir):
    store = _store(store_dir)
    cold = search_frontier(store, min_job_duration_s=0.0)
    from repro.whatif import default_families
    seeds = seed_points(default_families(), cold.frontier)
    assert any(seeds.values())              # the Pareto set maps back
    warm = search_frontier(store, min_job_duration_s=0.0,
                           init_frontier=cold.frontier)
    # the cold knee is evaluated in round 0 of the warm search
    warm_round0_keys = warm.history[0].n_evals_total
    assert any(o.params == cold.knee.params
               for o in warm.frontier.outcomes[:warm_round0_keys])
    assert np.isclose(warm.knee.saved_fraction, cold.knee.saved_fraction,
                      atol=0.01)
    # warm start also loads from a saved frontier JSON
    import pathlib
    from repro.whatif import save_frontier
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "frontier.json"
        save_frontier(cold.frontier, path)
        warm2 = search_frontier(store, min_job_duration_s=0.0,
                                init_frontier=str(path))
    assert warm2.n_evals == warm.n_evals


def test_warm_start_respects_tight_eval_budget(store_dir):
    """Seeds ride along only as far as the budget allows: a max_evals that
    exactly covers the coarse grids stays valid with init_frontier."""
    store = _store(store_dir)
    from repro.whatif import default_families
    fams = default_families(composites=False)
    cold = search_frontier(store, families=fams, min_job_duration_s=0.0)
    coarse_n = 1 + sum(len(f.coarse_points()) for f in fams)  # + noop
    warm = search_frontier(store, families=fams, max_evals=coarse_n,
                           min_job_duration_s=0.0,
                           init_frontier=cold.frontier)
    assert warm.n_evals <= coarse_n


def test_sidecar_save_preserves_concurrent_appends():
    """save_sidecar merges its manifest key atomically into the on-disk
    manifest — shards appended by another handle since this one opened
    must survive the derived-data write."""
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=2, horizon_s=1200, seed=5, store=store,
                         shard_s=600)
        ir = build_ir(store, IRConfig())
        writer = TelemetryStore(d)          # a concurrent appender
        generate_cluster(n_devices=1, horizon_s=600, seed=9, store=writer,
                         shard_s=600)
        n_shards = len(writer.manifest["shards"])
        assert n_shards > len(store.manifest["shards"])
        save_sidecar(ir, store)
        fresh = TelemetryStore(d)
        assert len(fresh.manifest["shards"]) == n_shards
        assert ir.config.config_hash() in fresh.manifest["run_ir"]

"""Incremental IR append (ISSUE 9): extend ≡ build, watermark-keyed cache,
and analyze-on-runs ≡ analyze-on-rows.

Three load-bearing contracts:

* ``IRBuilder.extend(ir, chunks)`` is **bit-identical** to a from-scratch
  ``build_ir`` over the full shard sequence — run tables, power columns and
  every *seeded* replay memo (prefix sums, §2.2 relabels, cap buckets)
  agree bit for bit, for any cut point and any append order.
* ``get_ir`` across a store append serves a ``memory_extend`` hit whose
  untouched streams are the *same objects* (memo caches intact) — an
  append must not evict the rest of the fleet's IRs.
* ``analyze_store(compact=...)`` matches the row oracle: times/counts/
  durations/intervals/platforms exact, energies <= 1e-9 relative,
  ``unattributed_energy_j`` exact — including under quarantined-shard
  coverage < 1.
"""
import math
import pathlib
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

import repro.obs as obs
from repro.cluster import generate_cluster
from repro.telemetry import TelemetryStore
from repro.telemetry.pipeline import analyze_store
from repro.whatif.ir import IRBuilder, IRConfig, build_ir, get_ir


# --------------------------------------------------------------------------- #
# Shared corpus: one generated store, chunks = (frame, host) in manifest order
# --------------------------------------------------------------------------- #
_CORPUS = None


def _corpus():
    """Module-cached store + chunks. A plain function (not a pytest
    fixture) so the offline hypothesis shim's zero-arg @given wrapper can
    reach it too; the tempdir is cleaned at interpreter exit."""
    global _CORPUS
    if _CORPUS is None:
        import atexit
        import shutil
        d = tempfile.mkdtemp(prefix="ir_append_corpus_")
        atexit.register(shutil.rmtree, d, True)
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=6, horizon_s=1800, seed=9,
                         store=store, shard_s=450)
        chunks = [(store.read_shard(s["file"]), s["host"])
                  for s in store.manifest["shards"]]
        assert len(chunks) >= 6
        _CORPUS = (d, chunks)
    return _CORPUS


@pytest.fixture(scope="module")
def corpus():
    return _corpus()


def _build(chunks, config):
    b = IRBuilder(config)
    for frame, host in chunks:
        b.update(frame, host_label=host)
    return b.finalize(source_rows=sum(len(f) for f, _ in chunks),
                      source_shards=len(chunks))


def _assert_ir_equal(got, want):
    assert sorted(got.streams) == sorted(want.streams)
    assert got.source_rows == want.source_rows
    assert got.source_shards == want.source_shards
    assert got.unattributed == want.unattributed
    for key in want.streams:
        g, w = got.streams[key], want.streams[key]
        assert g.host_label == w.host_label
        assert g.platform_id == w.platform_id
        assert g.ts_first == w.ts_first
        for col in ("state", "low", "length", "power_sum", "power"):
            assert np.array_equal(getattr(g, col), getattr(w, col)), \
                (key, col)
        # every memo the extend seeded must bit-equal the from-scratch
        # derivation (the from-scratch stream computes it lazily here)
        for memo_key, seeded in g._cache.items():
            fresh = _fresh_memo(w, memo_key)
            _assert_memo_equal(seeded, fresh, (key, memo_key))


def _fresh_memo(s, memo_key):
    if memo_key == "cumres":
        return s.cum_resident()
    if memo_key == "off":
        return s.run_offsets()
    if memo_key == "res":
        return s.resident_runs()
    if memo_key == "ts":
        return s.ts()
    if isinstance(memo_key, tuple) and memo_key[0] == "base":
        return s.baseline(memo_key[1])
    if isinstance(memo_key, tuple) and memo_key[0] == "park":
        return s.parking_counterfactual(memo_key[1])
    if memo_key == "crs":
        return s.controller_runs()
    if isinstance(memo_key, tuple) and memo_key[0] == "final":
        return s.final_state(memo_key[1])
    if isinstance(memo_key, tuple) and memo_key[0] == "sfinal":
        return s.sample_final_state(memo_key[1])
    if isinstance(memo_key, tuple) and memo_key[0] == "caps":
        return s.cap_buckets(memo_key[1])
    if isinstance(memo_key, tuple) and memo_key[0] == "dscum":
        return s.downscale_cums(memo_key[1], memo_key[2], memo_key[3])
    raise AssertionError(f"unexpected seeded memo {memo_key!r}")


def _assert_memo_equal(a, b, ctx):
    if isinstance(a, dict):
        assert set(a) == set(b), ctx
        for k in a:
            _assert_memo_equal(a[k], b[k], ctx + (k,))
    elif isinstance(a, tuple):
        assert len(a) == len(b), ctx
        for x, y in zip(a, b):
            _assert_memo_equal(x, y, ctx)
    elif isinstance(a, np.ndarray):
        assert np.array_equal(a, b), ctx
    else:
        assert a == b, ctx


# --------------------------------------------------------------------------- #
# extend ≡ build, bit for bit, across random cuts and append orders
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_extend_matches_build_across_chunkings(seed):
    import random
    _, chunks = _corpus()
    rng = random.Random(seed)
    config = IRConfig()
    want = _build(chunks, config)
    # warm the oracle's expensive memos so seeded keys have a counterpart
    for s in want.streams.values():
        s.cap_buckets(3)
        s.downscale_cums(0.25, 20.0, 3)
    n = len(chunks)
    cut = rng.randint(1, n - 1)
    base = _build(chunks[:cut], config)
    if rng.random() < 0.5:
        # single catch-up append of the whole tail
        got = IRBuilder(config).extend(base, chunks[cut:])
    else:
        # two stacked appends: extend-of-extend must still be exact
        mid = rng.randint(cut, n - 1)
        step = IRBuilder(config).extend(base, chunks[cut:mid + 1])
        got = IRBuilder(config).extend(step, chunks[mid + 1:])
    _assert_ir_equal(got, want)


def test_extend_rejects_config_mismatch_and_dirty_builder(corpus):
    _, chunks = corpus
    base = _build(chunks[:3], IRConfig())
    other = IRConfig(dt_s=2.0)
    with pytest.raises(ValueError, match="different config"):
        IRBuilder(other).extend(base, chunks[3:4])
    dirty = IRBuilder(IRConfig())
    # a chunk with attributed rows, so the builder holds open accumulators
    attributed = next(
        (f, h) for f, h in chunks if np.any(np.asarray(f["job_id"]) >= 0))
    dirty.update(attributed[0], host_label=attributed[1])
    assert dirty._acc
    with pytest.raises(ValueError, match="fresh"):
        dirty.extend(base, chunks[3:4])


# --------------------------------------------------------------------------- #
# get_ir across a store append: watermark-keyed cache, no eviction of the
# untouched fleet
# --------------------------------------------------------------------------- #
def test_get_ir_extends_in_place_without_evicting_untouched_streams(corpus):
    src_dir, _ = corpus
    src = TelemetryStore(src_dir)
    shards = src.manifest["shards"]
    last = shards[-1]
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(pathlib.Path(d) / "grow",
                               shard_format="npy_dir")
        for s in shards[:-1]:
            store.write_shard(src.read_shard(s["file"]), host=s["host"])
        ir1 = get_ir(store, IRConfig())
        for s in ir1.streams.values():        # populate memo caches
            s.final_state(3)
            s.cap_buckets(3)
        ids = {k: v for k, v in ir1.streams.items()}

        store.write_shard(src.read_shard(last["file"]), host=last["host"])
        obs.enable()
        try:
            obs.reset()
            ir2 = get_ir(store, IRConfig())
            text = obs.render_prometheus()
        finally:
            obs.disable()
            obs.reset()
        # the appended store is served by extension, not a rebuild
        assert 'repro_ir_cache_hits_total{level="memory_extend"} 1' in text
        assert "repro_ir_cache_misses_total" not in text
        assert 'repro_ir_appends_total' in text

        assert ir2.source_rows == store.total_rows
        # streams of other hosts are untouched: SAME objects, memos intact
        for k, s2 in ir2.streams.items():
            if s2.host_label != last["host"]:
                assert s2 is ids[k]
                assert ("final", 3) in s2._cache
        # appended-to streams were replaced with memo-seeded rebuilds
        touched = [k for k, s2 in ir2.streams.items()
                   if k in ids and s2 is not ids[k]]
        assert touched
        for k in touched:
            assert ("final", 3) in ir2.streams[k]._cache
            assert ("caps", 3) in ir2.streams[k]._cache
        # and extension is exact: bit-identical to a from-scratch build
        want = build_ir(store, IRConfig())
        _assert_ir_equal(ir2, want)
        # a further acquisition with no growth is a plain memory hit
        assert get_ir(store, IRConfig()) is ir2


# --------------------------------------------------------------------------- #
# analyze-on-runs ≡ analyze-on-rows
# --------------------------------------------------------------------------- #
def _assert_analysis_matches(run, row, unattributed_exact=True):
    assert len(run.jobs) == len(row.jobs)
    for a, b in zip(run.jobs, row.jobs):       # sorted stream order, both
        assert a.job_id == b.job_id
        assert a.platform == b.platform
        assert a.duration_s == b.duration_s
        assert a.breakdown.time_s == b.breakdown.time_s
        assert a.intervals == b.intervals
        for st_ in a.breakdown.energy_j:
            assert math.isclose(a.breakdown.energy_j[st_],
                                b.breakdown.energy_j[st_],
                                rel_tol=1e-9, abs_tol=1e-9)
    assert run.n_intervals == row.n_intervals
    assert run.fleet.time_s == row.fleet.time_s
    assert sorted(run.platforms) == sorted(row.platforms)
    for p in run.platforms:
        assert run.platforms[p].time_s == row.platforms[p].time_s
    if unattributed_exact:
        assert run.unattributed_energy_j == row.unattributed_energy_j
    assert run.coverage == row.coverage
    assert run.skipped == row.skipped


def test_analyze_compact_matches_row_oracle(corpus):
    src_dir, _ = corpus
    store = TelemetryStore(src_dir)
    row = analyze_store(store, min_job_duration_s=600.0, compact=False)
    run = analyze_store(store, min_job_duration_s=600.0, compact=True)
    _assert_analysis_matches(run, row)
    assert run.coverage == 1.0
    # jobs carry their platform and the per-platform map is non-trivial
    assert all(j.platform >= 0 for j in run.jobs)
    assert run.platforms


def test_analyze_compact_matches_rows_under_quarantine(corpus):
    src_dir, _ = corpus
    src = TelemetryStore(src_dir)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(pathlib.Path(d) / "dirty",
                               shard_format="npy_dir")
        for s in src.manifest["shards"]:
            store.write_shard(src.read_shard(s["file"]), host=s["host"])
        # corrupt the trailing shard of one host: the stream now ends a
        # shard early but stays regular, so the IR path survives too
        victim = store.manifest["shards"][-1]
        vdir = pathlib.Path(store.root) / victim["file"]
        col = next(iter(vdir.iterdir()))
        col.write_bytes(b"corrupt")
        row = analyze_store(store, min_job_duration_s=600.0,
                            compact=False, strict=False)
        run = analyze_store(store, min_job_duration_s=600.0, strict=False)
        assert 0.0 < run.coverage < 1.0
        assert len(run.skipped) == 1
        _assert_analysis_matches(run, row)
        # strict callers still refuse degraded data on every path
        with pytest.raises(Exception):
            analyze_store(store, min_job_duration_s=600.0, compact=True)


def test_analyze_accepts_prebuilt_ir_handle(corpus):
    src_dir, _ = corpus
    store = TelemetryStore(src_dir)
    ir = get_ir(store, IRConfig())
    via_handle = analyze_store(store, min_job_duration_s=600.0,
                               compact=True, ir=ir)
    auto = analyze_store(store, min_job_duration_s=600.0, compact=True)
    assert via_handle.fleet.time_s == auto.fleet.time_s
    assert via_handle.unattributed_energy_j == auto.unattributed_energy_j
    # a mismatched handle is refused, not silently misused
    from repro.whatif.ir import IRUnsupportedError
    with pytest.raises(IRUnsupportedError):
        analyze_store(store, min_job_duration_s=600.0, compact=True,
                      ir=ir, dt_s=2.0)

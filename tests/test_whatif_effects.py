"""Effect algebra and policy composition: identity/associativity laws and
composite bit-identity.

The load-bearing guarantees of the ISSUE-4 refactor: (1) ``compose`` with
the identity effect is bit-exact (``compose(NoOp, P)`` replays identically
to ``P`` alone); (2) a :class:`CompositePolicy` is bit-identical under any
chunking and process-pool width; (3) the batched :class:`CompositeBatch`
path equals scalar sequential application on random composite grids; and
(4) composite event pricing charges each part's events at that part's own
per-event cost.
"""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.cluster import generate_cluster
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.telemetry import TelemetryStore
from repro.telemetry.records import TelemetryFrame
from repro.whatif import (BatchedPolicyReplayer, CompositePolicy,
                          DownscalePolicy, NoOpPolicy, ParkingPolicy,
                          PolicyReplayer, PowerCapPolicy, compose,
                          frontier_to_dict, identity_effect, make_batches,
                          policy_event_prices, price_events, run_sweep,
                          sweep_frame)


def _job_frame(cs):
    return cs.frame


def _replay(policy, frame, chunk=None, **kw):
    kw.setdefault("min_job_duration_s", 300)
    rep = PolicyReplayer(policy, **kw)
    if chunk is None:
        rep.update(frame)
    else:
        for c in frame.iter_chunks(chunk):
            rep.update(c)
    return rep.finalize()


def _assert_results_equal(a, b):
    assert [j.job_id for j in a.jobs] == [j.job_id for j in b.jobs]
    for ja, jb in zip(a.jobs, b.jobs):
        assert ja.baseline.energy_j == jb.baseline.energy_j
        assert ja.counterfactual.energy_j == jb.counterfactual.energy_j
        assert ja.counterfactual.time_s == jb.counterfactual.time_s
        assert ja.penalty_s == jb.penalty_s
        assert ja.wake_events == jb.wake_events
        assert ja.downscale_events == jb.downscale_events
        assert ja.throttled_time_s == jb.throttled_time_s
    assert a.counterfactual.energy_j == b.counterfactual.energy_j
    assert a.penalty_s == b.penalty_s


# --------------------------------------------------------------------------- #
# algebra laws on raw effects
# --------------------------------------------------------------------------- #
def test_compose_identity_is_bit_exact():
    cs = generate_cluster(n_devices=2, horizon_s=1200, seed=5)
    from repro.core.power_model import get_platform
    plat = get_platform("l40s")
    pol = DownscalePolicy()
    for key, seg in cs.frame.group_streams():
        if key[0] < 0:
            continue
        eff, _ = pol.apply(seg, plat, pol.init_carry())
        eff.events = np.array([eff.wake_events], dtype=np.int64)
        ident = identity_effect(seg)
        out = compose(ident, eff)
        assert out.power_w is eff.power_w
        assert out.resident is eff.resident
        assert np.array_equal(out.throttled, eff.throttled)
        assert out.penalty_partial_s == eff.penalty_partial_s
        assert out.wake_events == eff.wake_events
        assert np.array_equal(out.events, eff.events)
        break


def test_compose_is_associative():
    rng = np.random.default_rng(0)
    n = 50

    def eff(seed):
        r = np.random.default_rng(seed)
        from repro.whatif import SegmentEffect
        return SegmentEffect(
            power_w=r.uniform(50, 400, n),
            resident=None if seed % 2 else r.random(n) < 0.5,
            throttled=r.random(n) < 0.3,
            penalty_partial_s=float(r.uniform(0, 5)),
            wake_events=int(r.integers(0, 4)),
            downscale_events=int(r.integers(0, 4)),
            events=r.integers(0, 4, 3).astype(np.int64),
        )

    a, b, c = eff(1), eff(2), eff(3)
    left = compose(compose(a, b), c)
    right = compose(a, compose(b, c))
    assert left.power_w is right.power_w
    assert np.array_equal(left.throttled, right.throttled)
    assert np.array_equal(left.events, right.events)
    assert left.wake_events == right.wake_events
    # residency: last non-None override either way
    la = left.resident if left.resident is not None else None
    ra = right.resident if right.resident is not None else None
    assert (la is None) == (ra is None)
    if la is not None:
        assert np.array_equal(la, ra)


def test_compose_rejects_mismatched_channel_spaces():
    from repro.whatif import SegmentEffect
    n = 4
    base = dict(power_w=np.ones(n), resident=None,
                throttled=np.zeros(n, bool))
    with pytest.raises(ValueError, match="channel"):
        compose(SegmentEffect(**base, events=np.zeros(2, dtype=np.int64)),
                SegmentEffect(**base, events=np.zeros(3, dtype=np.int64)))
    with pytest.raises(ValueError, match="lift"):
        compose(SegmentEffect(**base),
                SegmentEffect(**base, events=np.zeros(1, dtype=np.int64)))


# --------------------------------------------------------------------------- #
# compose(NoOp, P) == P through the replayer (the identity law, end to end)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("inner", [
    DownscalePolicy(),
    ParkingPolicy(pool=PoolConfig(n_devices=2,
                                  policy=PoolPolicy.CONSOLIDATED, n_active=1),
                  resume_latency_s=7.0),
    PowerCapPolicy(cap_fraction=0.5),
])
def test_noop_composition_is_bit_identical_to_bare_policy(inner):
    cs = generate_cluster(n_devices=3, horizon_s=2400, seed=13)
    bare = _replay(inner, cs.frame)
    for parts in ((NoOpPolicy(), inner), (inner, NoOpPolicy())):
        comp = _replay(CompositePolicy(parts), cs.frame)
        _assert_results_equal(bare, comp)


# --------------------------------------------------------------------------- #
# composite bit-identity: chunking, workers, batched vs scalar sequential
# --------------------------------------------------------------------------- #
def _random_composite_grid(rng):
    """Random grids of composites (park+downscale, downscale+cap, 3-part)
    mixed with their leaf constituents."""
    grid = [NoOpPolicy()]
    for _ in range(int(rng.integers(1, 3))):
        n_dev = int(rng.choice([2, 4]))
        park = ParkingPolicy(
            pool=PoolConfig(n_devices=n_dev, policy=PoolPolicy.CONSOLIDATED,
                            n_active=int(rng.integers(1, n_dev))),
            resume_latency_s=float(rng.uniform(2.0, 40.0)))
        down = DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)),
            cooldown_y_s=float(rng.uniform(1.0, 10.0)),
            mode=rng.choice([DownscaleMode.SM_ONLY, DownscaleMode.SM_AND_MEM]),
        ))
        grid.append(CompositePolicy((park, down)))
        grid.append(park)
        grid.append(down)
    cap = PowerCapPolicy(cap_fraction=float(rng.uniform(0.3, 0.9)))
    grid.append(CompositePolicy((DownscalePolicy(), cap)))
    if rng.random() < 0.5:
        grid.append(CompositePolicy((
            ParkingPolicy(pool=PoolConfig(n_devices=4,
                                          policy=PoolPolicy.CONSOLIDATED,
                                          n_active=2)),
            DownscalePolicy(), cap)))
    order = rng.permutation(len(grid))
    return [grid[i] for i in order]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_batched_composite_matches_scalar_sequential(seed):
    rng = np.random.default_rng(seed % 100000)
    grid = _random_composite_grid(rng)
    shard_s = int(rng.choice([300, 700, 1500]))
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=6, horizon_s=1500,
                         seed=int(rng.integers(0, 100)),
                         store=store, shard_s=shard_s)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        ref = run_sweep(store, grid, workers=1, min_job_duration_s=300,
                        batched=False)
        for workers in (1, 2):
            # compact=False: this test pins the row-batched engine to the
            # per-policy reference bit-for-bit; the run-IR fast path has its
            # own equivalence suite in tests/test_whatif_ir.py
            bat = run_sweep(store, grid, workers=workers,
                            min_job_duration_s=300, batched=True,
                            compact=False)
            assert frontier_to_dict(bat) == frontier_to_dict(ref)


def test_composite_chunking_bit_identical():
    cs = generate_cluster(n_devices=4, horizon_s=2700, seed=21)
    comp = CompositePolicy((
        ParkingPolicy(pool=PoolConfig(n_devices=2,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=1), resume_latency_s=9.0),
        DownscalePolicy(config=ControllerConfig(threshold_x_s=1.0,
                                                cooldown_y_s=2.0)),
    ))
    mono = _replay(comp, cs.frame)
    for chunk_rows in (1, 97, 997):
        _assert_results_equal(mono, _replay(comp, cs.frame, chunk=chunk_rows))
    # batched replayer, chunked, same grid position
    for chunk_rows in (97, 997):
        rep = BatchedPolicyReplayer([comp], min_job_duration_s=300)
        for chunk in cs.frame.iter_chunks(chunk_rows):
            rep.update(chunk)
        _assert_results_equal(mono, rep.finalize()[0])


def test_composites_group_into_structure_batches():
    pd = CompositePolicy((ParkingPolicy(pool=PoolConfig(
        n_devices=4, policy=PoolPolicy.CONSOLIDATED, n_active=2)),
        DownscalePolicy()))
    pd2 = CompositePolicy((ParkingPolicy(pool=PoolConfig(
        n_devices=8, policy=PoolPolicy.CONSOLIDATED, n_active=4)),
        DownscalePolicy(config=ControllerConfig(threshold_x_s=2.0))))
    dc = CompositePolicy((DownscalePolicy(), PowerCapPolicy()))
    batches = make_batches([pd, dc, pd2, NoOpPolicy()])
    names = [type(b).__name__ for b, _ in batches]
    assert names == ["CompositeBatch", "CompositeBatch", "NoOpBatch"]
    # same part structure -> same batch, grid order preserved
    (b0, idx0), (b1, idx1), _ = batches
    assert idx0 == [0, 2] and len(b0.policies) == 2
    assert idx1 == [1]


# --------------------------------------------------------------------------- #
# per-part event pricing
# --------------------------------------------------------------------------- #
def test_composite_prices_each_parts_events_at_its_own_cost():
    # device 1 of a 1-of-2 pool parks; alternating idle/active decades
    # produce parking wakes AND downscale restores on the same stream
    rows = []
    for t in range(60):
        active = (t // 10) % 2 == 0
        rows.append({
            "timestamp": float(t), "job_id": 3, "device_id": 1, "hostname": 0,
            "program_resident": 1, "sm": 80.0 if active else 1.0,
            "power": 250.0 if active else 105.0, "platform": 0,
        })
    frame = TelemetryFrame.from_rows(rows)
    park = ParkingPolicy(pool=PoolConfig(n_devices=2,
                                         policy=PoolPolicy.CONSOLIDATED,
                                         n_active=1), resume_latency_s=7.0)
    down = DownscalePolicy(config=ControllerConfig(threshold_x_s=1.0,
                                                   cooldown_y_s=2.0))
    comp = CompositePolicy((park, down))
    from repro.core.power_model import get_platform
    plat = get_platform("l40s")
    prices = policy_event_prices(comp, plat)
    assert len(prices) == 2
    assert prices[0] == park.event_penalty_s(plat) == 7.0
    assert prices[1] == down.event_penalty_s(plat)

    res_comp = _replay(comp, frame, min_job_duration_s=0.0)
    res_park = _replay(park, frame, min_job_duration_s=0.0)
    res_down = _replay(down, frame, min_job_duration_s=0.0)
    # parking wakes are unchanged by composition (parking runs first);
    # each part's events are priced at that part's own per-event cost
    assert res_park.wake_events == 2
    counts = np.array([res_park.wake_events,
                       res_comp.wake_events - res_park.wake_events])
    assert res_comp.penalty_s == pytest.approx(
        price_events(prices, counts))
    # and the parking component alone contributes 2 * 7 s
    assert res_comp.penalty_s >= 2 * 7.0
    assert res_down.downscale_events > 0   # the stream does trigger downscale


def test_composite_validation():
    with pytest.raises(ValueError, match="at least one part"):
        CompositePolicy(())
    with pytest.raises(ValueError, match="Policy protocol"):
        CompositePolicy((NoOpPolicy(), object()))


def test_composite_frontier_roundtrip_and_label():
    cs = generate_cluster(n_devices=2, horizon_s=1500, seed=23)
    comp = CompositePolicy((
        ParkingPolicy(pool=PoolConfig(n_devices=2,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=1)),
        DownscalePolicy(),
    ))
    frontier = sweep_frame(cs.frame, [NoOpPolicy(), comp],
                           min_job_duration_s=300)
    from repro.whatif import format_frontier, frontier_from_dict
    payload = frontier_to_dict(frontier)
    assert frontier_from_dict(payload) == frontier
    text = format_frontier(frontier)
    assert "parking 1-of-2" in text and "downscale" in text

"""JAX replay backend (ISSUE 6): NumPy-oracle equivalence, pack_ir
padding/bucketing properties, mesh-shape invariance, and the integrator
port.

The backend contract under test: **time and count metrics are
bit-identical** to the NumPy run-level replay (integer sample sums and
identical Algorithm-1 decision sequences), **energies and penalties agree
to <= 1e-9 relative** (float summation order differs), and results are
independent of padding bucket layout and of the config-axis mesh shape.
"""
import tempfile

import numpy as np
import pytest
from _hyp import given, settings, st

jax = pytest.importorskip("jax")

from repro.cluster import generate_cluster
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.energy import integrate_runs
from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.states import DEFAULT_CLASSIFIER
from repro.telemetry import TelemetryStore
from repro.telemetry.records import TelemetryFrame
from repro.whatif import (CompositePolicy, DownscalePolicy, IRConfig,
                          NoOpPolicy, ParkingPolicy, PowerCapPolicy,
                          build_ir, default_policy_grid, evaluate, get_ir,
                          run_sweep, search_frontier)
from repro.whatif import backend as B
from repro.whatif.ir import ir_config_for
from repro.whatif.policies import DownscaleBatch, _run_downscale
from repro.whatif.replay import _resolve_platform
from repro.whatif.sweep import resolve_backend

EXACT_FIELDS = ("name", "params", "n_jobs", "wake_events",
                "downscale_events", "throttled_time_s")
FLOAT_FIELDS = ("baseline_energy_j", "counterfactual_energy_j",
                "energy_saved_j", "saved_fraction", "penalty_s",
                "penalty_fraction", "exec_idle_energy_fraction_baseline",
                "exec_idle_energy_fraction_cf")


def assert_outcomes_equivalent(ref, cmp_, exact_energies=False):
    assert len(ref) == len(cmp_)
    for a, b in zip(ref, cmp_):
        for f in EXACT_FIELDS:
            assert getattr(a, f) == getattr(b, f), (a.name, a.params, f)
        for f in FLOAT_FIELDS:
            if exact_energies:
                assert getattr(a, f) == getattr(b, f), (a.name, a.params, f)
            else:
                assert np.isclose(getattr(a, f), getattr(b, f),
                                  rtol=1e-9, atol=1e-9), (a.name, a.params, f)
        for f in ("per_job_saved_fraction", "per_job_penalty_s"):
            if exact_energies:
                assert getattr(a, f) == getattr(b, f), (a.name, a.params, f)
            else:
                np.testing.assert_allclose(getattr(a, f), getattr(b, f),
                                           rtol=1e-9, atol=1e-9)


@pytest.fixture(scope="module")
def store_dir():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        generate_cluster(n_devices=6, horizon_s=1500, seed=7, store=store,
                         shard_s=500)
        yield d


def _store(store_dir):
    return TelemetryStore(store_dir)


def family_grid():
    """Every IR-capable family, including the parking+downscale composite."""
    park = ParkingPolicy(pool=PoolConfig(n_devices=4,
                                         policy=PoolPolicy.CONSOLIDATED,
                                         n_active=2),
                         resume_latency_s=12.0)
    return default_policy_grid(dense=False) + [
        CompositePolicy((park, DownscalePolicy())),
        CompositePolicy((park, DownscalePolicy(config=ControllerConfig(
            threshold_x_s=3.0, cooldown_y_s=9.0,
            mode=DownscaleMode.SM_AND_MEM)))),
    ]


# --------------------------------------------------------------------------- #
# backend selection
# --------------------------------------------------------------------------- #
def test_resolve_backend():
    assert resolve_backend("numpy") == "numpy"
    assert resolve_backend("jax") == "jax"
    assert resolve_backend("auto") == "jax"      # jax is importable here
    with pytest.raises(ValueError, match="unknown backend"):
        resolve_backend("tpu")


# --------------------------------------------------------------------------- #
# oracle equivalence: full family set, >= 2 mesh shapes
# --------------------------------------------------------------------------- #
def test_jax_matches_oracle_full_families_and_mesh_shapes(store_dir):
    store = _store(store_dir)
    grid = family_grid()
    ref = evaluate(grid, store, compact=True, min_job_duration_s=0.0)
    for dist in (None, B.config_mesh(1), B.config_mesh(4)):
        out = evaluate(grid, store, backend="jax", dist=dist,
                       min_job_duration_s=0.0)
        assert_outcomes_equivalent(ref, out)


def test_jax_matches_oracle_interval_and_duration_variants(store_dir):
    store = _store(store_dir)
    grid = family_grid()
    for mjd, mis in ((300.0, 5.0), (0.0, 1.0), (0.0, 10.0)):
        ref = evaluate(grid, store, compact=True, min_job_duration_s=mjd,
                       min_interval_s=mis)
        out = evaluate(grid, store, backend="jax", min_job_duration_s=mjd,
                       min_interval_s=mis)
        assert_outcomes_equivalent(ref, out)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=3, deadline=None)
def test_jax_matches_oracle_random_grid_and_chunking(seed):
    """Random family mixes — including configs the IR cannot host, which
    the jax path must route through the NumPy row fallback — over random
    shard chunkings. run_sweep comparison also covers Pareto flags."""
    rng = np.random.default_rng(seed % 100000)
    grid = [NoOpPolicy()]
    for _ in range(int(rng.integers(1, 4))):
        grid.append(DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)),
            cooldown_y_s=float(rng.uniform(1.0, 10.0)),
            interval_eps_s=float(rng.choice([0.5, 1.0, 2.0])),
            mode=rng.choice([DownscaleMode.SM_ONLY,
                             DownscaleMode.SM_AND_MEM]))))
    n_dev = int(rng.choice([2, 4]))
    grid.append(ParkingPolicy(
        pool=PoolConfig(n_devices=n_dev, policy=PoolPolicy.CONSOLIDATED,
                        n_active=int(rng.integers(1, n_dev))),
        resume_latency_s=float(rng.uniform(2.0, 40.0))))
    for _ in range(int(rng.integers(1, 3))):
        grid.append(PowerCapPolicy(
            cap_fraction=float(rng.uniform(0.3, 0.9))))
    grid.append(CompositePolicy((
        ParkingPolicy(pool=PoolConfig(n_devices=4,
                                      policy=PoolPolicy.CONSOLIDATED,
                                      n_active=2),
                      resume_latency_s=float(rng.uniform(2.0, 30.0))),
        DownscalePolicy(config=ControllerConfig(
            threshold_x_s=float(rng.uniform(0.5, 8.0)))),
    )))
    if rng.random() < 0.5:
        # foreign low-activity threshold: IR-unsupported, row fallback
        grid.append(DownscalePolicy(config=ControllerConfig(
            activity_threshold=0.03)))
    order = rng.permutation(len(grid))
    grid = [grid[i] for i in order]
    shard_s = int(rng.choice([300, 700, 1500]))
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=4, horizon_s=1200,
                         seed=int(rng.integers(0, 100)),
                         store=store, shard_s=shard_s)
        ref = run_sweep(store, grid, min_job_duration_s=300.0)
        cmp_ = run_sweep(store, grid, min_job_duration_s=300.0,
                         backend="jax")
        assert cmp_.n_rows == ref.n_rows and cmp_.n_runs == ref.n_runs
        assert_outcomes_equivalent(ref.outcomes, cmp_.outcomes)
        assert [o.pareto for o in ref.outcomes] == \
            [o.pareto for o in cmp_.outcomes]


def test_search_jax_matches_numpy_trajectory(store_dir):
    store = _store(store_dir)
    ref = search_frontier(store, min_job_duration_s=0.0)
    out = search_frontier(store, min_job_duration_s=0.0, backend="jax")
    assert out.n_evals == ref.n_evals
    assert out.knee.params == ref.knee.params
    assert np.isclose(out.knee.saved_fraction, ref.knee.saved_fraction,
                      rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------- #
# pack_ir properties: round-trip, padding isolation, retrace bounds
# --------------------------------------------------------------------------- #
def test_pack_ir_roundtrip_bit_identical(store_dir):
    from repro.core.power_model import ClockLevel

    store = _store(store_dir)
    ir = get_ir(store, ir_config_for([DownscalePolicy()]))
    min_samples = 5
    packed = B.pack_ir(ir, min_samples, min_job_duration_s=0.0)
    assert packed.n_streams == len(ir.select(None))
    views = packed.unpack()
    for s, plat, v in zip(packed.streams, packed.platforms, views):
        off, low_flags = s.controller_runs()
        low_j = np.flatnonzero(low_flags)
        np.testing.assert_array_equal(v["lr_s0"], off[low_j])
        np.testing.assert_array_equal(v["lr_len"],
                                      off[low_j + 1] - off[low_j])
        np.testing.assert_array_equal(
            v["lr_busy"],
            s.ts_first + s.dt_s * off[low_j + 1].astype(np.float64))
        np.testing.assert_array_equal(v["cum_res"], s.cum_resident())
        for j, (sm, mem) in enumerate(((ClockLevel.MIN, ClockLevel.MAX),
                                       (ClockLevel.MIN, ClockLevel.MIN))):
            delta = plat.exec_idle_w - plat.residency_floor_w(sm, mem)
            ce, ca = s.downscale_cums(float(delta), plat.deep_idle_w,
                                      min_samples)
            np.testing.assert_array_equal(v["ds_cum"][2 * j], ce)
            np.testing.assert_array_equal(v["ds_cum"][2 * j + 1], ca)
        cap = s.cap_buckets(min_samples)
        for st_key in (0, 1, 2):
            sp, top = v["cap_buckets"][st_key]
            np.testing.assert_array_equal(sp, cap[st_key][0])
            np.testing.assert_array_equal(top, cap[st_key][1])
        sp, top = v["cap_buckets"]["penalty"]
        np.testing.assert_array_equal(sp, cap["penalty"][0])
        np.testing.assert_array_equal(top, cap["penalty"][2])
        pk = s.parking_counterfactual(min_samples)
        np.testing.assert_array_equal(v["pk_state"], pk["cf_state"])
        np.testing.assert_array_equal(
            v["pk_energy"],
            pk["keep_sum"] + pk["idle_len"] * plat.deep_idle_w)
        np.testing.assert_array_equal(v["pk_len"], s.length)
        assert v["ts_first"] == s.ts_first
    # the pack is cached on the IR: same key, same object
    assert B.pack_ir(ir, min_samples, min_job_duration_s=0.0) is packed


def test_pack_ir_padding_never_leaks(store_dir):
    """Forcing every stream into one giant padding bucket (pad_floor
    crank) must leave outcomes EXACTLY identical — fired padding lanes
    would shift energies, counts, or CDFs."""
    store = _store(store_dir)
    grid = family_grid()
    ir = get_ir(store, ir_config_for(grid))
    ref, _, _ = B.replay_ir_outcomes(ir, grid, min_job_duration_s=0.0)
    big, _, _ = B.replay_ir_outcomes(ir, grid, min_job_duration_s=0.0,
                                     pad_floor=2048)
    packed_small = B.pack_ir(ir, 5, min_job_duration_s=0.0)
    packed_big = B.pack_ir(ir, 5, min_job_duration_s=0.0, pad_floor=2048)
    assert len(packed_big.buckets) <= len(packed_small.buckets)
    assert len(packed_big.buckets) == 1
    assert_outcomes_equivalent(ref, big, exact_energies=True)


def test_pack_ir_retrace_counts(store_dir):
    """Retraces stay bounded by the number of distinct padding buckets,
    and a repeat replay compiles nothing new."""
    store = _store(store_dir)
    grid = family_grid()
    ir = get_ir(store, ir_config_for(grid))
    before = dict(B.TRACE_COUNTS)
    B.replay_ir_outcomes(ir, grid, min_job_duration_s=0.0)
    packed = B.pack_ir(ir, 5, min_job_duration_s=0.0)
    after_first = dict(B.TRACE_COUNTS)
    n_buckets = len(packed.buckets)
    for name in ("downscale", "powercap", "integrate"):
        delta = after_first.get(name, 0) - before.get(name, 0)
        assert 0 <= delta <= n_buckets, (name, delta, n_buckets)
    B.replay_ir_outcomes(ir, grid, min_job_duration_s=0.0)
    assert dict(B.TRACE_COUNTS) == after_first


# --------------------------------------------------------------------------- #
# integrator port
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_jax_integrate_runs_matches_numpy(seed):
    rng = np.random.default_rng(seed % 100000)
    n_runs, n_cfg = 150, 4
    states = rng.choice([0, 1, 2], size=n_runs).astype(np.int32)
    lengths = rng.integers(1, 12, size=n_runs)
    energy = rng.normal(100, 30, (n_cfg, n_runs)) * lengths
    min_samples = int(rng.integers(0, 8))
    ref = integrate_runs(states, energy, lengths, min_samples, dt_s=1.0)
    out = B.jax_integrate_runs(states, energy, lengths, min_samples,
                               dt_s=1.0)
    assert len(ref) == len(out)
    for a, b in zip(ref, out):
        assert a.time_s == b.time_s                 # bit-identical
        for k in a.energy_j:
            assert np.isclose(a.energy_j[k], b.energy_j[k],
                              rtol=1e-9, atol=1e-9)


# --------------------------------------------------------------------------- #
# backend misuse is loud
# --------------------------------------------------------------------------- #
def test_backend_validation_errors(store_dir):
    from repro.core.states import ClassifierConfig

    store = _store(store_dir)
    grid = [DownscalePolicy()]
    ir = get_ir(store, ir_config_for(grid))
    with pytest.raises(ValueError, match="classifier"):
        B.replay_ir_outcomes(
            ir, grid,
            classifier=ClassifierConfig(activity_threshold_pct=10.0))
    with pytest.raises(ValueError, match="dt_s"):
        B.replay_ir_outcomes(ir, grid, dt_s=2.0)
    park = ParkingPolicy(pool=PoolConfig(n_devices=2,
                                         policy=PoolPolicy.CONSOLIDATED,
                                         n_active=1))
    with pytest.raises(ValueError):
        # downscale-then-parking composite is not IR-capable
        B.replay_ir_outcomes(ir, [CompositePolicy((DownscalePolicy(),
                                                   park))])


# --------------------------------------------------------------------------- #
# cooldown-suppression pass: decision sequences pinned (satellite #2)
# --------------------------------------------------------------------------- #
def _cooldown_frame():
    """Six cycles of [10 low-activity samples][3 busy samples]: short busy
    gaps make every later low run cooldown-risky for large-Y configs."""
    rows = []
    t = 0.0
    for _ in range(6):
        for sm, n in ((1.0, 10), (95.0, 3)):
            for _ in range(n):
                rows.append({"timestamp": t, "job_id": 1,
                             "program_resident": 1,
                             "power": 300.0 if sm > 50 else 80.0, "sm": sm,
                             "hostname": 0, "device_id": 0, "platform": 0})
                t += 1.0
    return TelemetryFrame.from_rows(rows)


def _naive_decisions(stream, dt_s, y, trig):
    """Transparent per-(run, config) sequential reference for the fire
    sequence: full-window searchsorted, no risky screen, no hoisting."""
    off, low_flags = stream.controller_runs()
    low_j = np.flatnonzero(low_flags)
    s0s = off[low_j]
    e0s = off[low_j + 1]
    lens = e0s - s0s
    ts = stream.ts()
    busy_after = stream.ts_first + dt_s * e0s.astype(np.float64)
    n_cfg = y.shape[0]
    fires = np.zeros((low_j.size, n_cfg), dtype=bool)
    last_busy = np.full(n_cfg, -np.inf)
    for k in range(low_j.size):
        for c in range(n_cfg):
            i = max(int(trig[c]), int(np.searchsorted(
                ts[s0s[k]:e0s[k]], last_busy[c] + y[c], side="left")))
            if lens[k] > trig[c] and i < lens[k]:
                fires[k, c] = True
                last_busy[c] = busy_after[k]
    return fires


def test_downscale_cooldown_decisions_pinned():
    grid = [DownscalePolicy(config=ControllerConfig(
        threshold_x_s=x, cooldown_y_s=y))
        for x, y in ((2.0, 1.0), (2.0, 10.0), (6.0, 10.0), (2.0, 20.0))]
    batch = DownscaleBatch(tuple(grid))
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        store.write_shard(_cooldown_frame(), host="h0")
        ir = build_ir(store, IRConfig())
        s = list(ir.streams.values())[0]
        plat = _resolve_platform(None, {}, s.platform_id)
        n_down, n_rest, throttled, _, _ = _run_downscale(
            s, plat, 1, 1.0, batch._eps, batch._x, batch._y, batch._trig,
            batch._delta(plat))
        fires = _naive_decisions(s, 1.0, batch._y, batch._trig)
        np.testing.assert_array_equal(n_down,
                                      fires.sum(axis=0).astype(np.int64))
        # pinned sequences: (x=2,y=1) fires every run untouched; (x=2,y=10)
        # and (x=6,y=10) fire every run but cooldown delays the trigger
        # index (visible as fewer throttled samples); (x=2,y=20)'s cooldown
        # overshoots the whole next run, so every other run is suppressed
        np.testing.assert_array_equal(n_down, [6, 6, 6, 3])
        np.testing.assert_array_equal(n_rest, [6, 6, 6, 3])
        np.testing.assert_array_equal(
            fires[:, 3], [True, False, True, False, True, False])
        assert throttled[1] < throttled[0]
        assert throttled[2] < throttled[1]
        # and the jax backend reproduces the same decision sequence
        out, _, _ = B.replay_ir_outcomes(ir, grid, min_job_duration_s=0.0,
                                         min_interval_s=1.0)
        np.testing.assert_array_equal(
            [o.downscale_events for o in out], n_down)
        np.testing.assert_array_equal(
            [int(o.throttled_time_s) for o in out], throttled)

"""Offline fallback for `hypothesis`.

CI containers have no network, so `hypothesis` may be absent. Property-test
modules import `given`/`settings`/`st` from here: when the real library is
installed it is re-exported unchanged; otherwise `@given` degrades to a small
fixed set of seeded pseudo-random examples — far less search power, but the
properties still execute and the suite collects offline.
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    st = strategies
except ModuleNotFoundError:
    import random

    _N_EXAMPLES = 5

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(draw)

    st = strategies

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            # deliberately zero-arg (no functools.wraps): pytest must not see
            # the strategy parameters of `fn` and mistake them for fixtures
            def wrapper():
                # seed on the test name so examples are stable across runs
                rng = random.Random(f"hypshim:{fn.__name__}")
                for _ in range(_N_EXAMPLES):
                    drawn = [s.example(rng) for s in arg_strats]
                    kw = {name: s.example(rng) for name, s in kw_strats.items()}
                    fn(*drawn, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(**_kw):
        def deco(fn):
            return fn

        return deco

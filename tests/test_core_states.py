"""Unit + property tests for the paper's core: states, intervals, energy."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.energy import integrate, merge
from repro.core.intervals import (apply_min_duration, duration_percentiles,
                                  extract_intervals, runs)
from repro.core.states import (ClassifierConfig, DeviceState, classify_sample,
                               classify_series, in_execution_mask,
                               state_time_fractions)


# --------------------------------------------------------------------------- #
# classifier (§2.2)
# --------------------------------------------------------------------------- #
def test_deep_idle_when_not_resident():
    assert classify_sample({"program_resident": False, "sm": 99.0}) \
        == DeviceState.DEEP_IDLE


def test_execution_idle_all_signals_low():
    s = {"program_resident": True, "sm": 1.0, "tensor": 0.0, "dram": 2.0,
         "pcie_tx": 0.1, "pcie_rx": 0.2}
    assert classify_sample(s) == DeviceState.EXECUTION_IDLE


def test_active_if_any_signal_high():
    base = {"program_resident": True, "sm": 0.0, "dram": 0.0}
    assert classify_sample({**base, "sm": 5.0}) == DeviceState.ACTIVE
    assert classify_sample({**base, "dram": 50.0}) == DeviceState.ACTIVE
    assert classify_sample({**base, "pcie_rx": 1.5}) == DeviceState.ACTIVE


def test_missing_signal_omitted_not_violated():
    # only sm available and low -> execution-idle (nan = unavailable)
    s = {"program_resident": True, "sm": 1.0, "dram": float("nan")}
    assert classify_sample(s) == DeviceState.EXECUTION_IDLE


@given(
    resident=st.lists(st.booleans(), min_size=1, max_size=200),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=50, deadline=None)
def test_states_mutually_exclusive_exhaustive(resident, seed):
    """The three states partition every sample (paper §2.2)."""
    rng = np.random.default_rng(seed)
    n = len(resident)
    resident = np.array(resident)
    sm = rng.uniform(0, 100, n)
    states = classify_series(resident, {"sm": sm}, {})
    # exhaustive: every sample classified
    assert set(np.unique(states)) <= {0, 1, 2}
    # deep-idle iff not resident
    assert np.all((states == int(DeviceState.DEEP_IDLE)) == ~resident)
    # active iff resident and sm >= 5
    assert np.all((states == int(DeviceState.ACTIVE)) == (resident & (sm >= 5.0)))
    fractions = state_time_fractions(states)
    assert abs(sum(fractions.values()) - 1.0) < 1e-9


@given(st.integers(0, 2**31 - 1), st.integers(1, 50))
@settings(max_examples=30, deadline=None)
def test_threshold_monotonicity(seed, n_jobs):
    """A more permissive activity threshold can only grow exec-idle time."""
    rng = np.random.default_rng(seed)
    n = 500
    resident = np.ones(n, bool)
    sm = rng.uniform(0, 30, n)
    lo = classify_series(resident, {"sm": sm}, {},
                         ClassifierConfig(activity_threshold_pct=2.0))
    hi = classify_series(resident, {"sm": sm}, {},
                         ClassifierConfig(activity_threshold_pct=10.0))
    assert np.sum(hi == int(DeviceState.EXECUTION_IDLE)) >= \
        np.sum(lo == int(DeviceState.EXECUTION_IDLE))


# --------------------------------------------------------------------------- #
# intervals (§2.2 / §4.4)
# --------------------------------------------------------------------------- #
def test_runs_partition_series():
    states = np.array([0, 0, 1, 1, 1, 2, 1, 1, 0])
    rs = list(runs(states))
    assert sum(r.duration for r in rs) == len(states)
    assert [r.state for r in rs] == [DeviceState.DEEP_IDLE,
                                     DeviceState.EXECUTION_IDLE,
                                     DeviceState.ACTIVE,
                                     DeviceState.EXECUTION_IDLE,
                                     DeviceState.DEEP_IDLE]


def test_min_duration_threshold():
    # 3s idle run dropped at 5s threshold, kept at 1s threshold
    states = np.array([2, 2, 1, 1, 1, 2, 2, 1, 1, 1, 1, 1, 2])
    assert len(extract_intervals(states, min_duration_s=5)) == 1
    assert len(extract_intervals(states, min_duration_s=1)) == 2


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_apply_min_duration_conservative(seed):
    """Relabeling short idles can only reduce measured exec-idle time, and
    never touches deep-idle samples."""
    rng = np.random.default_rng(seed)
    states = rng.choice([0, 1, 2], 300, p=[0.2, 0.3, 0.5]).astype(np.int8)
    out = apply_min_duration(states, min_duration_s=5)
    assert np.sum(out == 1) <= np.sum(states == 1)
    assert np.array_equal(out == 0, states == 0)


# --------------------------------------------------------------------------- #
# energy accounting
# --------------------------------------------------------------------------- #
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_energy_conservation(seed):
    """Per-state energies sum to total integrated energy."""
    rng = np.random.default_rng(seed)
    n = 400
    states = rng.choice([0, 1, 2], n).astype(np.int8)
    power = rng.uniform(30, 400, n)
    bd = integrate(states, power, min_duration_s=None)
    assert bd.total_energy_j == pytest.approx(float(power.sum()))
    assert bd.total_time_s == pytest.approx(n)
    # in-execution fractions bounded
    assert 0.0 <= bd.exec_idle_energy_fraction <= 1.0
    assert 0.0 <= bd.exec_idle_time_fraction <= 1.0


def test_merge_additive():
    rng = np.random.default_rng(0)
    parts = []
    total = 0.0
    for _ in range(5):
        states = rng.choice([0, 1, 2], 100).astype(np.int8)
        power = rng.uniform(30, 300, 100)
        parts.append(integrate(states, power, min_duration_s=None))
        total += power.sum()
    merged = merge(parts)
    assert merged.total_energy_j == pytest.approx(total)


def test_in_execution_mask():
    states = np.array([0, 1, 2, 0, 1])
    assert list(in_execution_mask(states)) == [False, True, True, False, True]

"""Chunked/streaming fleet analysis must equal the monolithic path exactly.

The tentpole guarantee of the streaming engine: any chunking of the same
telemetry — 1-row chunks, prime-sized chunks, shard-aligned chunks, or a
:class:`TelemetryStore` on disk — produces a bit-identical
:class:`FleetAnalysis` (fractions, interval counts, per-job CDFs), including
execution-idle runs deliberately split across chunk boundaries.
"""
import tempfile

import numpy as np
import pytest

from repro.cluster import generate_cluster
from repro.core.states import DeviceState
from repro.telemetry import (FleetAccumulator, TelemetryFrame, TelemetryStore,
                             analyze_fleet, analyze_store)
from repro.telemetry.pipeline import per_job_fraction_cdf


def assert_fleet_equal(a, b, unattributed_exact=True):
    assert [j.job_id for j in a.jobs] == [j.job_id for j in b.jobs]
    assert a.n_intervals == b.n_intervals
    assert a.fleet.time_s == b.fleet.time_s
    assert a.fleet.energy_j == b.fleet.energy_j
    for ja, jb in zip(a.jobs, b.jobs):
        assert ja.duration_s == jb.duration_s
        assert ja.breakdown.time_s == jb.breakdown.time_s
        assert ja.breakdown.energy_j == jb.breakdown.energy_j
        assert [(i.start, i.end) for i in ja.intervals] == \
            [(i.start, i.end) for i in jb.intervals]
    ca, cb = per_job_fraction_cdf(a.jobs), per_job_fraction_cdf(b.jobs)
    assert np.array_equal(ca["time_fraction"], cb["time_fraction"])
    assert np.array_equal(ca["energy_fraction"], cb["energy_fraction"])
    if unattributed_exact:
        assert a.unattributed_energy_j == b.unattributed_energy_j
    else:
        # partial sums follow the chunk partition -> last-ulp differences
        assert a.unattributed_energy_j == pytest.approx(
            b.unattributed_energy_j, rel=1e-12)


def streamed(frame, chunk_rows, **kw):
    acc = FleetAccumulator(**kw)
    for chunk in frame.iter_chunks(chunk_rows):
        acc.update(chunk)
    return acc.finalize()


# --------------------------------------------------------------------------- #
# seeded cluster, awkward chunk sizes
# --------------------------------------------------------------------------- #
def test_cluster_chunked_equals_monolithic():
    cs = generate_cluster(n_devices=4, horizon_s=2700, seed=13)
    mono = analyze_fleet(cs.frame, min_job_duration_s=600)
    assert mono.jobs, "fixture must contain analyzable jobs"
    for chunk_rows in (997, 2700, len(cs.frame)):   # prime, shard-ish, whole
        fa = streamed(cs.frame, chunk_rows, min_job_duration_s=600)
        assert_fleet_equal(fa, mono, unattributed_exact=False)


def test_one_row_chunks_equal_monolithic():
    # 1 s chunks: every sample is its own update; carry logic does all work
    rows = []
    rng = np.random.default_rng(4)
    for t in range(240):
        active = (t // 17) % 3 != 1      # alternating active / idle blocks
        rows.append({
            "timestamp": float(t), "job_id": 7, "device_id": 0, "hostname": 0,
            "program_resident": 1, "sm": 60.0 if active else 1.0,
            "dram": 40.0 if active else 0.5,
            "power": float(rng.uniform(80, 300)),
        })
    frame = TelemetryFrame.from_rows(rows)
    mono = analyze_fleet(frame, min_job_duration_s=0.0)
    fa = streamed(frame, 1, min_job_duration_s=0.0)
    assert_fleet_equal(fa, mono, unattributed_exact=True)
    assert fa.n_intervals > 0


# --------------------------------------------------------------------------- #
# execution-idle run split across a chunk boundary
# --------------------------------------------------------------------------- #
def _phase_frame(spec):
    """spec: list of (n_seconds, active?) for one resident job at 1 Hz."""
    rows, t = [], 0
    for n, active in spec:
        for _ in range(n):
            rows.append({
                "timestamp": float(t), "job_id": 1, "device_id": 0,
                "hostname": 0, "program_resident": 1,
                "sm": 80.0 if active else 1.0,
                "power": 250.0 if active else 90.0,
            })
            t += 1
    return TelemetryFrame.from_rows(rows)


def test_sustained_idle_run_split_across_boundary():
    # 6 s idle run split 3+3 by the chunk boundary: must still count as ONE
    # sustained (>=5 s) interval with all 6 samples' energy
    frame = _phase_frame([(10, True), (6, False), (10, True)])
    mono = analyze_fleet(frame, min_job_duration_s=0.0)
    assert mono.n_intervals == 1
    assert mono.fleet.time_s[DeviceState.EXECUTION_IDLE] == 6.0
    assert mono.fleet.energy_j[DeviceState.EXECUTION_IDLE] == 6 * 90.0
    for chunk_rows in (13, 1, 5):        # 13 splits the idle run at 3+3
        fa = streamed(frame, chunk_rows, min_job_duration_s=0.0)
        assert_fleet_equal(fa, mono)
        assert fa.jobs[0].intervals[0].start == 10
        assert fa.jobs[0].intervals[0].end == 16


def test_short_idle_run_split_across_boundary_relabelled():
    # 3 s idle run split 2+1: shorter than the 5 s sustain rule, so both
    # paths must relabel it ACTIVE — no interval, no exec-idle energy
    frame = _phase_frame([(6, True), (3, False), (6, True)])
    mono = analyze_fleet(frame, min_job_duration_s=0.0)
    fa = streamed(frame, 8, min_job_duration_s=0.0)   # boundary inside the run
    assert mono.n_intervals == fa.n_intervals == 0
    assert fa.fleet.time_s[DeviceState.EXECUTION_IDLE] == 0.0
    assert fa.fleet.energy_j[DeviceState.ACTIVE] == \
        mono.fleet.energy_j[DeviceState.ACTIVE]
    assert_fleet_equal(fa, mono)


# --------------------------------------------------------------------------- #
# storage path: generate into a store, analyze out-of-core
# --------------------------------------------------------------------------- #
def test_analyze_store_equals_monolithic():
    mono_cs = generate_cluster(n_devices=4, horizon_s=1800, seed=21)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        s_cs = generate_cluster(n_devices=4, horizon_s=1800, seed=21,
                                store=store, shard_s=600)
        assert len(s_cs.frame) == 0                  # nothing materialized
        assert store.total_rows == len(mono_cs.frame)
        assert len(store.manifest["shards"]) >= 4 * 3  # chunked emission
        mono = analyze_fleet(mono_cs.frame, min_job_duration_s=600)
        # compact=False: the row engine is the bit-exactness oracle vs the
        # monolithic pass (the IR engine matches energies only to 1e-9)
        fa = analyze_store(store, min_job_duration_s=600, compact=False)
        assert_fleet_equal(fa, mono, unattributed_exact=False)


# --------------------------------------------------------------------------- #
# grouping + ordering contracts
# --------------------------------------------------------------------------- #
def test_group_streams_zero_copy_and_sorted():
    cs = generate_cluster(n_devices=2, horizon_s=900, seed=2)
    seen = []
    for key, seg in cs.frame.group_streams():
        seen.append(key)
        ts = seg["timestamp"]
        assert np.all(np.diff(ts) >= 0)
        assert seg["timestamp"].base is not None    # slice view, not a copy
        assert np.all(seg["job_id"] == key[0])
    assert seen == sorted(seen)
    assert sum(len(seg) for _, seg in cs.frame.group_streams()) == len(cs.frame)


def test_out_of_order_chunks_rejected():
    frame = _phase_frame([(10, True)])
    acc = FleetAccumulator(min_job_duration_s=0.0)
    chunks = list(frame.iter_chunks(5))
    acc.update(chunks[1])
    with pytest.raises(ValueError, match="not time-ordered"):
        acc.update(chunks[0])


def test_duplicate_boundary_timestamp_accepted():
    # the monolithic path's stable sort tolerates duplicate timestamps, so
    # the streaming path must too — wherever the chunk boundary falls
    rows = [{"timestamp": float(min(t, 5)), "job_id": 1, "device_id": 0,
             "hostname": 0, "program_resident": 1, "sm": 50.0, "power": 100.0}
            for t in range(12)]                      # ts: 0..5,5,5,...
    frame = TelemetryFrame.from_rows(rows)
    mono = analyze_fleet(frame, min_job_duration_s=0.0)
    for chunk_rows in (4, 7, 1):                     # boundaries inside dups
        fa = streamed(frame, chunk_rows, min_job_duration_s=0.0)
        assert_fleet_equal(fa, mono)


def test_dt_s_plumbs_through_entry_points():
    # 2 s sampling: 150 rows = 300 s of telemetry; with dt_s=2 both time and
    # energy integrate per-sample x dt, and the sustain rule counts seconds
    rows = [{"timestamp": float(2 * t), "job_id": 5, "device_id": 0,
             "hostname": 0, "program_resident": 1, "sm": 50.0, "power": 200.0}
            for t in range(150)]
    frame = TelemetryFrame.from_rows(rows)
    fa = analyze_fleet(frame, min_job_duration_s=200, dt_s=2.0)
    assert [j.job_id for j in fa.jobs] == [5]
    assert fa.fleet.time_s[DeviceState.ACTIVE] == 300.0
    assert fa.fleet.energy_j[DeviceState.ACTIVE] == 150 * 200.0 * 2.0
    fa1 = streamed(frame, 37, min_job_duration_s=200, dt_s=2.0)
    assert_fleet_equal(fa1, fa)


# --------------------------------------------------------------------------- #
# process-pool parallel shard analysis + accumulator merge
# --------------------------------------------------------------------------- #
def test_analyze_store_workers_bit_identical_to_serial():
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=6, horizon_s=1800, seed=33,
                         store=store, shard_s=600)
        assert len({s["host"] for s in store.manifest["shards"]}) > 1
        serial = analyze_store(store, min_job_duration_s=600, compact=False)
        parallel = analyze_store(store, min_job_duration_s=600, workers=2,
                                 compact=False)
    # fully exact, including unattributed (fsum over identical partials)
    assert_fleet_equal(parallel, serial, unattributed_exact=True)


def test_analyze_store_accepts_one_shot_hosts_iterable():
    # `hosts` may be a generator; it is consumed by both the partitioner and
    # the serial fallback, which must not silently yield an empty analysis
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=4, horizon_s=1200, seed=34,
                         store=store, shard_s=600)
        expected = analyze_store(store, hosts=["h0"], min_job_duration_s=300)
        assert expected.jobs
        got = analyze_store(store, hosts=(h for h in ["h0"]),
                            min_job_duration_s=300, workers=4)
        assert_fleet_equal(got, expected, unattributed_exact=True)


def test_accumulator_merge_disjoint_streams():
    cs = generate_cluster(n_devices=4, horizon_s=1200, seed=35)
    mono = FleetAccumulator(min_job_duration_s=300)
    mono.update(cs.frame)
    expected = mono.finalize()

    devs = cs.frame["device_id"]
    a = FleetAccumulator(min_job_duration_s=300)
    b = FleetAccumulator(min_job_duration_s=300)
    a.update(cs.frame.select(devs < 2))
    b.update(cs.frame.select(devs >= 2))
    merged = a.merge(b).finalize()
    assert_fleet_equal(merged, expected, unattributed_exact=False)


def test_accumulator_merge_rejects_overlap_and_config_mismatch():
    cs = generate_cluster(n_devices=2, horizon_s=900, seed=36)
    a = FleetAccumulator(min_job_duration_s=0.0)
    b = FleetAccumulator(min_job_duration_s=0.0)
    a.update(cs.frame)
    b.update(cs.frame)
    with pytest.raises(ValueError, match="overlapping"):
        a.merge(b)
    c = FleetAccumulator(min_job_duration_s=123.0)
    with pytest.raises(ValueError, match="configs"):
        a.merge(c)


# --------------------------------------------------------------------------- #
# storage: npy_dir shard format + mmap reads
# --------------------------------------------------------------------------- #
def test_npy_dir_store_roundtrip_and_mmap_zero_copy():
    cs = generate_cluster(n_devices=2, horizon_s=900, seed=37)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        store.write_shard(cs.frame, host="h0")
        plain = store.read_shard(store.manifest["shards"][0]["file"])
        for f in plain.columns:
            assert np.array_equal(plain[f], cs.frame[f], equal_nan=True)
        mapped = next(store.iter_shards(mmap=True))
        assert isinstance(mapped["power"], np.memmap)   # zero-copy column
        assert np.array_equal(np.asarray(mapped["power"]), cs.frame["power"])
        mono = analyze_fleet(cs.frame, min_job_duration_s=300)
        fa = analyze_store(store, min_job_duration_s=300, mmap=True,
                           compact=False)
        assert_fleet_equal(fa, mono, unattributed_exact=False)


def test_npz_store_mmap_falls_back_to_load():
    cs = generate_cluster(n_devices=1, horizon_s=600, seed=38)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)                       # default npz
        store.write_shard(cs.frame, host="h0")
        frame = next(store.iter_shards(mmap=True))      # no error, plain load
        assert np.array_equal(frame["power"], cs.frame["power"])


def test_unknown_shard_format_rejected():
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="shard_format"):
            TelemetryStore(d, shard_format="parquet")


def test_shard_format_persisted_across_reopen():
    cs = generate_cluster(n_devices=1, horizon_s=300, seed=39)
    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d, shard_format="npy_dir")
        store.write_shard(cs.frame, host="h0")
        reopened = TelemetryStore(d)            # append keeps the format
        assert reopened.shard_format == "npy_dir"
        with pytest.raises(ValueError, match="persists"):
            TelemetryStore(d, shard_format="npz")
        # leftover shard dir from a crashed bulk write: overwrite, not crash
        fresh = TelemetryStore(d + "/sub", shard_format="npy_dir")
        fresh.write_shard(cs.frame, host="h0", flush_manifest=False)
        fresh2 = TelemetryStore(d + "/sub", shard_format="npy_dir")
        fresh2.write_shard(cs.frame, host="h0")
        assert fresh2.total_rows == len(cs.frame)


def test_min_job_duration_filters_on_span_not_row_count():
    # 2 s sampling: 150 rows span 299 s. The seed compared ROW COUNT against
    # seconds, which would wrongly drop this job for min_job_duration_s=200.
    rows = [{"timestamp": float(2 * t), "job_id": 5, "device_id": 0,
             "hostname": 0, "program_resident": 1, "sm": 50.0, "power": 200.0}
            for t in range(150)]
    fa = analyze_fleet(TelemetryFrame.from_rows(rows), min_job_duration_s=200)
    assert [j.job_id for j in fa.jobs] == [5]

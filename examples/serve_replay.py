"""Replay the paper's §5.3 experiment: Algorithm 1 vs baseline on the Azure
Code trace — then run the same controller against the LIVE JAX engine.

Run:  PYTHONPATH=src python examples/serve_replay.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.imbalance import PoolConfig
from repro.core.power_model import get_platform
from repro.models import api
from repro.serving.des import simulate_pool
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.latency import Request
from repro.serving.perf_model import LLAMA13B_L40S
from repro.traces import generate_trace, get_trace

# ---- 1. pool-scale: the paper's replay (L40S + Llama-13B perf model) -------
spec = get_trace("azure_code")
trace = generate_trace(spec, 1175.0, 1, seed=3)
perf = dataclasses.replace(LLAMA13B_L40S, busy_util=spec.busy_util)
plat = get_platform("l40s")

results = {}
for label, mode in (("baseline", None),
                    ("sm_only", DownscaleMode.SM_ONLY),
                    ("sm_mem", DownscaleMode.SM_AND_MEM)):
    cfg = None if mode is None else ControllerConfig(mode=mode)
    r = simulate_pool([dataclasses.replace(q) for q in trace], plat, perf,
                      PoolConfig(n_devices=1), 1175.0, controller_cfg=cfg,
                      tick_s=0.05)
    results[label] = r
    print(f"{label:9s} avg={r.avg_power_w:6.1f} W  p95={r.latency.p95_s:5.2f} s"
          f"  exec-idle {r.exec_idle_time_fraction:.0%} of time")

base = results["baseline"].avg_power_w
print(f"\nSM-only: -{1 - results['sm_only'].avg_power_w / base:.0%} power "
      f"(paper -22%); SM+mem: -{1 - results['sm_mem'].avg_power_w / base:.0%} "
      f"(paper -34%)")

# ---- 2. live engine: same controller on a real (smoke-size) model ----------
print("\nlive JAX engine (smoke gemma-2b, controller on):")
cfg = get_smoke_config("gemma-2b")
params = api.init_params(jax.random.PRNGKey(0), cfg)
engine = ServingEngine(cfg, params, EngineConfig(
    n_slots=2, max_seq_len=64, prefill_bucket=16, max_new_tokens=4,
    controller=True))
rng = np.random.default_rng(0)
small = [Request(req_id=i, arrival_s=float(i * 9), prompt_tokens=8,
                 output_tokens=4) for i in range(4)]
prompts = {r.req_id: rng.integers(2, cfg.vocab_size, 8).astype(np.int32)
           for r in small}
stats = engine.run(small, prompts)
frame = engine.sampler.frame()
print(f"served {stats.n} requests; telemetry rows {len(frame)}; "
      f"controller downscales: {engine.controller.stats.downscale_events}")

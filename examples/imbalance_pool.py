"""§5.1 demo: deliberate load imbalance on an 8-device pool.

Shows the paper's cautionary tale — pool utilization barely moves while
energy halves and p95 rises.

Run:  PYTHONPATH=src python examples/imbalance_pool.py
"""
import dataclasses

from repro.core.imbalance import PoolConfig, PoolPolicy
from repro.core.power_model import get_platform
from repro.serving.des import simulate_pool
from repro.serving.perf_model import LLAMA13B_L40S
from repro.traces import generate_trace, get_trace

spec = get_trace("azure_code")
spec = dataclasses.replace(spec, gap_median_s=spec.gap_median_s * 1.9)
trace = generate_trace(spec, 1200.0, n_devices=8, seed=2)
perf = dataclasses.replace(LLAMA13B_L40S, busy_util=spec.busy_util)
plat = get_platform("l40s")

base = None
for label, policy, k in (("8 active (balanced)", PoolPolicy.BALANCED, 8),
                         ("4 active", PoolPolicy.CONSOLIDATED, 4),
                         ("2 active", PoolPolicy.CONSOLIDATED, 2)):
    pool = PoolConfig(n_devices=8, policy=policy, n_active=k,
                      park_inactive=False, spill_every=13)
    r = simulate_pool([dataclasses.replace(q) for q in trace], plat, perf,
                      pool, 1200.0)
    if base is None:
        base = r
    print(f"{label:22s} energy={r.energy_j / base.energy_j:5.0%}  "
          f"p95={r.latency.p95_s:5.2f}s ({r.latency.p95_s / base.latency.p95_s - 1:+.0%})  "
          f"pool-SM-util={r.avg_sm_util:.3f}")

print("\nutilization stays flat while energy halves — utilization is not a"
      "\npower proxy (paper §5.1); latency is the price (paper Fig 10).")

"""The live fleet controller as a daemon: ingest → extend → search, forever.

The offline demos answer "what should the fleet do" once, from a frozen
store; this one keeps the answer fresh. A producer appends telemetry
shards continuously — the §2.1 cluster simulator drip-fed window by
window, or a directory of real DCGM / ``power.json`` collector dumps —
and a :class:`repro.live.LiveController` ticks against the store: poll
past the watermark, coalesce the backlog into one incremental-IR extend,
re-run the Pareto search warm-started from the previous frontier,
checkpoint atomically, publish the refreshed knee.

Kill it (``kill -9``, Ctrl-C, power cut) and relaunch with the same
``--checkpoint``/``--store``: it resumes from the checkpoint and converges
to the frontier the uninterrupted run would have produced — bit-identical
(tests/test_live.py proves it at every tick-phase boundary). Corrupt the
checkpoint and it cold-starts instead of crashing; poison a shard and it
serves the stale knee, flagged, with the watermark held.

Run:  PYTHONPATH=src python examples/live_controller.py \
          [--devices 8] [--hours 2] [--window 600] [--ticks 20]
          [--store DIR] [--checkpoint PATH] [--dcgm DIR]
          [--backend numpy|jax] [--max-evals 64] [--interval 0]
          [--out knee.json] [--metrics-out metrics.prom]
"""
import argparse
import pathlib
import sys
import tempfile

import repro.obs as obs
from repro.live import (DcgmDirectoryProducer, LiveConfig, LiveController,
                        SimulatorProducer)
from repro.telemetry import TelemetryStore


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="simulated fleet size (ignored with --dcgm)")
    ap.add_argument("--hours", type=float, default=2.0,
                    help="simulated horizon (ignored with --dcgm)")
    ap.add_argument("--window", type=int, default=600,
                    help="simulator window per shard, seconds")
    ap.add_argument("--ticks", type=int, default=20,
                    help="controller ticks to run (a real daemon loops "
                         "forever; the demo stops when the feed drains)")
    ap.add_argument("--interval", type=float, default=0.0,
                    help="sleep between ticks, seconds")
    ap.add_argument("--store", default=None,
                    help="telemetry store dir (default: a temp dir; pass a "
                         "real path to survive restarts)")
    ap.add_argument("--checkpoint", default=None,
                    help="controller checkpoint path (default: "
                         "<store>/live_ckpt.json)")
    ap.add_argument("--dcgm", default=None, metavar="DIR",
                    help="poll DIR for DCGM / power.json collector dumps "
                         "instead of simulating")
    ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"),
                    help="replay backend for the warm rung (the ladder "
                         "degrades jax -> numpy -> cold on failure)")
    ap.add_argument("--max-evals", type=int, default=64)
    ap.add_argument("--out", default=None,
                    help="published-knee JSON path (atomic rewrite per tick)")
    ap.add_argument("--metrics-out", default=None,
                    help="write the Prometheus exposition here on exit")
    args = ap.parse_args()

    obs.enable()
    obs.init_live_metrics()
    obs.init_degradation_metrics()

    tmp = None
    if args.store is None:
        tmp = tempfile.TemporaryDirectory()
        args.store = tmp.name
    store_dir = pathlib.Path(args.store)
    ckpt = pathlib.Path(args.checkpoint) if args.checkpoint \
        else store_dir / "live_ckpt.json"

    store = TelemetryStore(store_dir / "telemetry")
    if args.dcgm:
        producer = DcgmDirectoryProducer(store, args.dcgm)
    else:
        producer = SimulatorProducer(
            store, n_devices=args.devices,
            horizon_s=int(args.hours * 3600), window_s=args.window)
        # resume-aware drip: skip the windows already in the store
        for _ in range(len(store.manifest["shards"])):
            if producer.exhausted:
                break
            producer._t_next += producer.window_s

    ctrl = LiveController(store, ckpt, LiveConfig(
        backend=args.backend, max_evals=args.max_evals),
        publish_path=args.out)
    if ctrl.tick_no:
        print(f"resumed from {ckpt}: tick {ctrl.tick_no}, "
              f"{ctrl.n_shards} shards covered, knee "
              f"{'present' if ctrl.knee else 'absent'}")

    import time
    for _ in range(args.ticks):
        fed = producer.step()
        r = ctrl.tick()
        knee = r.knee
        knee_txt = ("knee none" if knee is None else
                    f"knee {knee.params} saves "
                    f"{knee.saved_fraction * 100:.1f}%")
        print(f"tick {r.tick:3d}  {r.result:9s}  +{fed} rows "
              f"{r.n_new_shards} shard(s) coalesced={r.coalesced}  "
              f"rung={r.rung or '-'}  staleness={r.staleness_s * 1e3:.0f}ms  "
              f"coverage={r.coverage:.3f}  {knee_txt}"
              + (f"  [{r.error}]" if r.error else ""))
        if r.result == "idle" and fed == 0 and not args.dcgm:
            print("feed drained — stopping (a real daemon keeps polling)")
            break
        if args.interval > 0:
            time.sleep(args.interval)

    if args.metrics_out:
        obs.write_textfile(args.metrics_out)
        print(f"metrics exposition -> {args.metrics_out}", file=sys.stderr)
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()

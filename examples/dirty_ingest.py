"""Dirty telemetry end-to-end: corrupt a corpus, scrub it, analyze anyway.

1. Generate a sharded corpus, then damage it the way production does:
   truncate one shard (torn copy), glitch power rails and duplicate
   timestamps in another, and ingest a ragged 1 Hz DCGM dump.
2. Scrub the store against the hygiene contract: repairable shards are
   rewritten in place, hopeless ones move to the quarantine area.
3. Analyze and sweep with `strict=False` — the pipeline completes, reports
   what it skipped, and the frontier carries its coverage fraction.

Run:  PYTHONPATH=src python examples/dirty_ingest.py
"""
import tempfile

import numpy as np

import repro.obs as obs
from repro.cluster import generate_cluster
from repro.telemetry import (TelemetryStore, analyze_store, ingest_dcgm,
                             scrub_store)
from repro.telemetry.records import TelemetryFrame
from repro.testing import faults
from repro.whatif import DownscalePolicy, NoOpPolicy, run_sweep

obs.enable()
obs.init_degradation_metrics()

with tempfile.TemporaryDirectory() as d:
    # 1. a healthy corpus ...
    store = TelemetryStore(d)
    generate_cluster(n_devices=8, horizon_s=1800, seed=42,
                     store=store, shard_s=600)

    # ... plus a ragged DCGM field dump (one missed SM sample, one glitch)
    verdict = ingest_dcgm(store, {
        "DCGM_FI_DEV_POWER_USAGE": [210.0] * 599 + [-3.0],
        "DCGM_FI_PROF_SM_ACTIVE": [0.62] * 598,
    }, host="h9", job_id=999)
    print(f"DCGM ingest: {verdict.status} {verdict.repairs}")

    # ... then production-grade damage
    names = [s["file"] for s in store.manifest["shards"]]
    faults.truncate_file(store.root / names[2])       # torn copy
    victim = store.read_shard(names[5])
    cols = {k: v.copy() for k, v in victim.columns.items()}
    cols["power"][::50] = -1.0                        # rail glitches
    dup = TelemetryFrame({k: np.concatenate([c, c[:30]])
                          for k, c in cols.items()})  # replayed samples
    store.rewrite_shard(names[5], dup)

    # 2. hygiene sweep: verdict per shard, manifest-recorded quarantine
    for v in scrub_store(TelemetryStore(d)):
        if v.status != "ok":
            print(f"  {v.shard}: {v.status} reasons={list(v.reasons)} "
                  f"repairs={v.repairs} rows {v.rows_in}->{v.rows_out}")

    # 3. tolerant analysis + sweep on whatever survived — with one MORE
    #    shard rotting after the scrub (full disks don't wait for sweeps):
    #    strict=False skips it mid-run and the coverage fraction says so
    scrubbed = TelemetryStore(d)
    faults.truncate_file(
        scrubbed.root / scrubbed.manifest["shards"][8]["file"])
    fleet = analyze_store(scrubbed, min_job_duration_s=600,
                          strict=False, verify=True)
    print(f"analyzed {len(fleet.jobs)} jobs at "
          f"coverage {fleet.coverage:.1%}; "
          f"exec-idle {fleet.in_execution_time_fraction:.1%} of time")

    frontier = run_sweep(scrubbed, [NoOpPolicy(), DownscalePolicy()],
                         min_job_duration_s=600, strict=False)
    best = max(frontier.outcomes, key=lambda o: o.energy_saved_j)
    print(f"sweep coverage {frontier.coverage:.1%}; best policy "
          f"{best.name} saves {best.saved_fraction:.1%}")

    print("\ndegradation ladder:")
    fam_names = {name for name, _, _ in obs.DEGRADATION_FAMILIES}
    for line in obs.render_prometheus().splitlines():
        if line.split("{")[0].split(" ")[0] in fam_names:
            print("  " + line)

"""Closed-loop what-if search: the best mitigation knobs under a budget.

The dense-grid sweep (examples/whatif_sweep.py) dumps 200 configs; an
operator wants one answer: *which knob setting saves the most energy while
staying under my performance-penalty budget?* This demo asks it closed-loop:

1. Simulate a fleet slice straight into a shard store.
2. Run :func:`repro.whatif.search_frontier`: evaluate each policy family's
   coarse grid in one batched replay, find the Pareto knee, then refine
   each family's continuous knobs around its knee-adjacent Pareto members —
   midpoint subdivision, one batched pass per round — until the knee stops
   moving or the config-evaluation budget runs out. The families include
   the composite the fixed grid cannot express: park the pool's inactive
   devices AND downscale the ones that keep serving
   (:class:`repro.whatif.CompositePolicy`).
3. Print the searched frontier, the knee, and the best config inside a
   1%-of-active-time penalty budget — plus the :mod:`repro.obs` stage tree
   for the whole ``ingest_to_knee`` trace (how stale is the answer, and
   where did the time go: IR build vs replay rounds).

Run:  PYTHONPATH=src python examples/whatif_search.py [--devices 16]
          [--hours 6] [--workers 2] [--max-evals 100]
          [--penalty-budget-pct 1.0] [--trace-out spans.jsonl]
"""
import argparse
import tempfile
import time

import repro.obs as obs
from repro.cluster import generate_cluster
from repro.core.energy import energy_kwh
from repro.telemetry import TelemetryStore
from repro.whatif import (PenaltyBudget, format_frontier, format_search_trace,
                          save_frontier, search_frontier)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--hours", type=float, default=6.0)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--max-evals", type=int, default=100)
    ap.add_argument("--penalty-budget-pct", type=float, default=1.0,
                    help="max modeled stall, %% of recorded active time")
    ap.add_argument("--out", default=None,
                    help="optional path for the searched-frontier JSON")
    ap.add_argument("--trace-out", default=None,
                    help="optional path for the span trace JSONL")
    args = ap.parse_args()

    obs.enable()

    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        generate_cluster(n_devices=args.devices,
                         horizon_s=int(args.hours * 3600), seed=42,
                         store=store, shard_s=6 * 3600)
        print(f"simulated {store.total_rows:,} device-seconds into "
              f"{len(store.manifest['shards'])} shards")

        budget = PenaltyBudget(
            max_penalty_fraction=args.penalty_budget_pct / 100.0)
        t0 = time.perf_counter()
        # one end-to-end span: IR build (inside the first evaluate) +
        # every search round — its duration is the staleness of the knee
        with obs.span("ingest_to_knee") as root:
            res = search_frontier(store, budget=budget,
                                  max_evals=args.max_evals,
                                  workers=args.workers,
                                  min_job_duration_s=7200)
            root.set(evals=res.n_evals, rounds=res.n_rounds)
        dt = time.perf_counter() - t0
        print(f"searched {res.n_evals} configs in {res.n_rounds} rounds "
              f"({dt:.1f}s, converged={res.converged}) — a dense sweep of "
              f"the same families is 200 configs\n")

    for i, r in enumerate(res.history):
        print(f"  round {i}: +{r.n_new:3d} configs (total {r.n_evals_total:3d})"
              f"  knee: {r.knee_saved_fraction:.1%} saved / "
              f"{r.knee_penalty_s:.0f}s penalty")
    print()
    print(format_frontier(res.frontier, top=12))

    knee = res.knee
    print(f"\nknee (diminishing returns): {knee.params} -> "
          f"{energy_kwh(knee.energy_saved_j):.2f} kWh "
          f"({knee.saved_fraction:.1%}) at {knee.penalty_s:.0f}s penalty")
    if res.best is not None:
        print(f"best within {args.penalty_budget_pct:.2g}% penalty budget: "
              f"{res.best.params} -> "
              f"{energy_kwh(res.best.energy_saved_j):.2f} kWh "
              f"({res.best.saved_fraction:.1%}) at "
              f"{res.best.penalty_fraction:.2%} of active time")
    else:
        print(f"no evaluated config fits a {args.penalty_budget_pct:.2g}% "
              f"penalty budget")

    print()
    print(format_search_trace(res.frontier))
    print("\nstage tree (knee staleness = root span):")
    print(obs.format_span_tree(min_dur_s=1e-3))

    if args.out:
        print(f"searched frontier written to "
              f"{save_frontier(res.frontier, args.out)}")
    if args.trace_out:
        print(f"span trace written to {obs.dump_spans_jsonl(args.trace_out)}")


if __name__ == "__main__":
    main()

"""End-to-end training driver: a ~100M-param qwen-family model for a few
hundred steps with checkpoint/restart, telemetry, and the execution-idle
controller guarding input-pipeline stalls.

On this CPU container the default is a scaled-down run (--steps 30); pass
--full for the ~100M/300-step version on real hardware.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps N] [--full]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config, get_smoke_config
from repro.telemetry import analyze_job
from repro.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=30)
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

if args.full:
    # ~110M params: qwen1.5-0.5b geometry at 12 layers
    cfg = dataclasses.replace(get_config("qwen1.5-0.5b"), n_layers=12,
                              name="qwen-100m")
    batch, seq, steps = 32, 512, max(args.steps, 300)
else:
    cfg = get_smoke_config("qwen1.5-0.5b")
    batch, seq, steps = 8, 64, args.steps

ckpt_dir = tempfile.mkdtemp(prefix="train100m_")
tc = TrainerConfig(steps=steps, checkpoint_every=max(steps // 3, 5),
                   checkpoint_dir=ckpt_dir, lr=1e-3)
trainer = Trainer(cfg, tc, global_batch=batch, seq_len=seq, controller=True)
report = trainer.run()
print(f"loss {report.losses[0]:.3f} -> {report.final_loss:.3f} over "
      f"{report.steps_run} steps ({report.wall_s:.0f}s wall), "
      f"checkpoints in {ckpt_dir}")

# restart from the checkpoint and keep training (fault-tolerance demo)
trainer2 = Trainer(cfg, dataclasses.replace(tc, steps=steps + 10),
                   global_batch=batch, seq_len=seq, controller=True)
report2 = trainer2.run()
print(f"resumed from step {report2.resumed_from}; "
      f"loss -> {report2.final_loss:.3f}")

frame = trainer2.sampler.frame()
if len(frame):
    ja = analyze_job(frame, job_id=1, min_duration_s=1.0)
    print(f"telemetry: exec-idle {ja.exec_idle_time_fraction:.1%} of step time "
          f"({ja.breakdown.total_energy_j/1e3:.1f} kJ simulated)")
assert report2.final_loss < report.losses[0], "training must make progress"
print("OK")

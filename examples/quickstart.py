"""Quickstart: the paper's pipeline end-to-end in ~30 lines.

1. Simulate a small cluster deployment (1 Hz telemetry).
2. Run the SAME analysis a production deployment would run: classify
   deep-idle / execution-idle / active, integrate energy, extract intervals.
3. Print the exec-idle exposure + what Algorithm 1 would have saved.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.cluster import generate_cluster
from repro.core.controller import ControllerConfig, DownscaleMode
from repro.core.states import DeviceState
from repro.telemetry import analyze_fleet

# 1. a day on a 12-device slice of the academic cluster
sample = generate_cluster(n_devices=12, horizon_s=8 * 3600, seed=42)
print(f"simulated {len(sample.frame):,} device-seconds, "
      f"{len(sample.job_classes)} jobs")

# 2. the paper's accounting (§2.2 classifier, >=5 s intervals, >=2 h jobs)
fleet = analyze_fleet(sample.frame, min_job_duration_s=7200)
print(f"long-running jobs analyzed: {len(fleet.jobs)}")
print(f"execution-idle: {fleet.in_execution_time_fraction:.1%} of "
      f"in-execution time, {fleet.in_execution_energy_fraction:.1%} of energy"
      f"  (paper: 19.7% / 10.7%)")

durations = np.array([iv.duration for j in fleet.jobs for iv in j.intervals])
if durations.size:
    print(f"{durations.size} execution-idle intervals; median "
          f"{np.median(durations):.0f}s, p90 {np.percentile(durations, 90):.0f}s"
          f"  (paper: 9s / 44s)")

# 3. counterfactual: Algorithm-1 savings if every exec-idle second had been
#    downscaled (SM+mem floor instead of full residency power)
saved = 0.0
for job in fleet.jobs:
    idle_j = job.breakdown.energy_j[DeviceState.EXECUTION_IDLE]
    idle_s = job.breakdown.time_s[DeviceState.EXECUTION_IDLE]
    plat_floor = 35.0  # L40S deep-idle watts (§5.3)
    saved += max(0.0, idle_j - idle_s * plat_floor)
total = fleet.fleet.total_energy_j
print(f"Algorithm-1 upper-bound saving: {saved / 3.6e6:.1f} kWh "
      f"({saved / total:.1%} of job energy) at the §5.3 latency trade-off")

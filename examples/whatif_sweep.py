"""What-if sweep: which execution-idle mitigation, at which knobs?

1. Simulate a day-scale slice of the academic cluster straight into a
   shard store (nothing fleet-sized is ever materialized).
2. Replay the stored telemetry under the default 200-config policy grid —
   Algorithm-1 downscaling (X x Y x mode), k-of-n consolidation parking,
   power capping — out-of-core, shard by shard, over a process pool, with
   each policy family evaluated as one (configs, samples) batch per
   stream segment (the config-axis batched replay).
3. Print the energy/perf trade-off frontier (Pareto set starred) and save
   the compact JSON report for dashboards.

For the budgeted alternative to the dense dump — closed-loop knob search
around the Pareto knee, including parking+downscale composites — see
examples/whatif_search.py.

Run:  PYTHONPATH=src python examples/whatif_sweep.py [--devices 16]
          [--hours 24] [--workers 2]
"""
import argparse
import tempfile
import time

from repro.cluster import generate_cluster
from repro.core.energy import energy_kwh
from repro.telemetry import TelemetryStore
from repro.whatif import (default_policy_grid, format_frontier, run_sweep,
                          save_frontier)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=16)
    ap.add_argument("--hours", type=float, default=24.0)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--out", default="reports/whatif_frontier.json")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        store = TelemetryStore(d)
        t0 = time.perf_counter()
        generate_cluster(n_devices=args.devices,
                         horizon_s=int(args.hours * 3600), seed=42,
                         store=store, shard_s=6 * 3600)
        print(f"simulated {store.total_rows:,} device-seconds into "
              f"{len(store.manifest['shards'])} shards "
              f"({time.perf_counter() - t0:.1f}s)")

        grid = default_policy_grid()
        t0 = time.perf_counter()
        frontier = run_sweep(store, grid, workers=args.workers,
                             min_job_duration_s=7200)
        dt = time.perf_counter() - t0
        print(f"swept {len(grid)} policy configs over {frontier.n_jobs} jobs "
              f"in {dt:.1f}s ({len(grid) / dt:.1f} configs/s, "
              f"workers={args.workers})\n")

    print(format_frontier(frontier, top=15))

    # an operator question the frontier answers directly: best saving under
    # a bounded modeled perf penalty
    budget_s = 0.001 * 3600 * args.hours * args.devices   # 0.1% of device-time
    best = frontier.best_within_penalty(budget_s)
    if best is not None:
        print(f"\nbest config within a {budget_s:.0f}s penalty budget: "
              f"{best.params} -> {energy_kwh(best.energy_saved_j):.2f} kWh "
              f"({best.saved_fraction:.1%}) saved")

    path = save_frontier(frontier, args.out)     # compact=True by default
    print(f"frontier JSON written to {path}")


if __name__ == "__main__":
    main()
